"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes × dtypes and ``assert_allclose`` each kernel (run with
``interpret=True`` on CPU) against these references.  The references are also
the fallback execution path (``REPRO_FORCE_REF=1``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "transpose", "segment_reduce", "window_scan", "linear_scan",
    "onehot_encode", "flash_attention", "decode_attention",
]


# -----------------------------------------------------------------------------
def transpose(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for block_transpose: plain 2-D transpose."""
    return x.T


# -----------------------------------------------------------------------------
def segment_reduce(values: jnp.ndarray, codes: jnp.ndarray, num_segments: int,
                   op: str = "sum") -> jnp.ndarray:
    """Oracle for segment_reduce: per-segment aggregate of ``values``.

    values: (M,) or (M, C) float32; codes: (M,) int32 in [-1, G).  Code -1
    (null/padding) contributes nothing.  Returns (G,) or (G, C).
    """
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    valid = codes >= 0
    safe = jnp.where(valid, codes, 0)
    if op == "sum":
        out = jax.ops.segment_sum(jnp.where(valid[:, None], v, 0.0), safe, num_segments)
    elif op == "count":
        ones = jnp.where(valid[:, None], 1.0, 0.0) * jnp.ones_like(v)
        out = jax.ops.segment_sum(ones, safe, num_segments)
    elif op == "min":
        big = jnp.asarray(jnp.finfo(v.dtype).max, v.dtype)
        out = jax.ops.segment_min(jnp.where(valid[:, None], v, big), safe, num_segments)
    elif op == "max":
        small = jnp.asarray(jnp.finfo(v.dtype).min, v.dtype)
        out = jax.ops.segment_max(jnp.where(valid[:, None], v, small), safe, num_segments)
    else:
        raise ValueError(op)
    return out[:, 0] if squeeze else out


# -----------------------------------------------------------------------------
def window_scan(x: jnp.ndarray, op: str = "cumsum") -> jnp.ndarray:
    """Oracle for window_scan: ordered cumulative op along axis 0 of (M, N)."""
    if op == "cumsum":
        return jnp.cumsum(x, axis=0)
    if op == "cummax":
        return jax.lax.cummax(x, axis=0)
    if op == "cummin":
        return jax.lax.cummin(x, axis=0)
    raise ValueError(op)


# -----------------------------------------------------------------------------
def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """Oracle for linear_scan: first-order recurrence h_t = a_t*h_{t-1} + b_t.

    a, b: (T, N).  Returns (T, N) of h_t.  This is the RG-LRU / SSM primitive.
    """
    if h0 is None:
        h0 = jnp.zeros_like(b[0])

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a, b))
    return hs


# -----------------------------------------------------------------------------
def onehot_encode(codes: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Oracle for onehot_encode: (M,) int32 → (M, G) f32; code -1 → all-zero."""
    eye = jax.nn.one_hot(jnp.where(codes >= 0, codes, num_classes), num_classes + 1)
    return eye[:, :num_classes].astype(jnp.float32)


# -----------------------------------------------------------------------------
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None) -> jnp.ndarray:
    """Oracle attention.  q,k,v: (H, S, D) (single sequence, multi-head) or
    (S, D).  GQA handled by the wrapper (repeating kv heads).  ``window``:
    local attention span (keys within [i-window+1, i])."""
    single = q.ndim == 2
    if single:
        q, k, v = q[None], k[None], v[None]
    h, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode-style)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    out = out.astype(q.dtype)
    return out[0] if single else out


# -----------------------------------------------------------------------------
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: int, scale: float | None = None) -> jnp.ndarray:
    """Oracle single-token GQA decode attention.

    q: (H, D) one new token's query heads; k_cache/v_cache: (S, KVH, D);
    ``length``: number of valid cache slots.  H = KVH * group.
    """
    h, d = q.shape
    s, kvh, _ = k_cache.shape
    group = h // kvh
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(kvh, group, d).astype(jnp.float32)
    kk = k_cache.astype(jnp.float32)
    vv = v_cache.astype(jnp.float32)
    logits = jnp.einsum("kgd,skd->kgs", qg, kk) * scale
    valid = (jnp.arange(s) < length)[None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("kgs,skd->kgd", p, vv)
    return out.reshape(h, d).astype(q.dtype)
