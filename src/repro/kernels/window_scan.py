"""WINDOW operator kernel: ordered cumulative functions (paper §3.3, §4.2).

WINDOW "does not admit row-wise parallelism because computation for each
subsequent row must wait for the result of the prior row" (paper §4.2).  The
TPU-native resolution: a *blocked scan* — each (TM, N) tile computes its local
cumulative in VMEM (log-depth on the VPU), then a running carry (1, N) scratch
bridges tiles across the sequential grid.  Cross-shard composition is a short
exclusive scan over per-shard totals (see physical.py), preserving exact
ordered semantics with parallel execution — the paper's WINDOW-parallelism
challenge resolved.

Supports multi-column application at once (N up to a VMEM-friendly width),
matching "WINDOW functions on multiple columns → column-based partitioning".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret

_OPS = ("cumsum", "cummax", "cummin")


def _scan_kernel(x_ref, o_ref, carry_ref, *, op: str):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        if op == "cumsum":
            carry_ref[...] = jnp.zeros_like(carry_ref)
        elif op == "cummax":
            carry_ref[...] = jnp.full_like(carry_ref, jnp.finfo(carry_ref.dtype).min)
        else:
            carry_ref[...] = jnp.full_like(carry_ref, jnp.finfo(carry_ref.dtype).max)

    x = x_ref[...].astype(jnp.float32)
    if op == "cumsum":
        local = jnp.cumsum(x, axis=0)
        out = local + carry_ref[...]
        carry_ref[...] = out[-1:, :]
    elif op == "cummax":
        local = jax.lax.cummax(x, axis=0)
        out = jnp.maximum(local, carry_ref[...])
        carry_ref[...] = out[-1:, :]
    else:
        local = jax.lax.cummin(x, axis=0)
        out = jnp.minimum(local, carry_ref[...])
        carry_ref[...] = out[-1:, :]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("op", "tm"))
def _window_scan_padded(x, op: str, tm: int):
    m, n = x.shape
    return pl.pallas_call(
        functools.partial(_scan_kernel, op=op),
        grid=(cdiv(m, tm),),
        in_specs=[pl.BlockSpec((tm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, n), jnp.float32)],
        interpret=use_interpret(),
    )(x)


def window_scan(x: jnp.ndarray, op: str = "cumsum", *, tile_m: int = 1024) -> jnp.ndarray:
    """Cumulative ``op`` along axis 0 of (M,) or (M, N) values (f32 out)."""
    assert op in _OPS, op
    squeeze = x.ndim == 1
    v = (x[:, None] if squeeze else x).astype(jnp.float32)
    m, n = v.shape
    if m == 0:
        return x.astype(jnp.float32)
    pad_val = {"cumsum": 0.0, "cummax": -jnp.inf, "cummin": jnp.inf}[op]
    tm = pick_tile(m, tile_m, SUBLANE)
    npad = ceil_to(n, LANE)
    vp = pad_axis(pad_axis(v, 0, ceil_to(m, tm)), 1, npad, value=pad_val)
    out = _window_scan_padded(vp, op, tm)[:m, :n]
    return out[:, 0] if squeeze else out
