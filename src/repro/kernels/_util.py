"""Shared utilities for the Pallas TPU kernels.

All kernels target TPU (MXU 128×128, VPU lanes of 8×128, VMEM ~16 MiB/core)
and are *validated* on CPU via ``interpret=True``, which runs the kernel body
in Python.  ``use_interpret()`` flips automatically on non-TPU backends.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

# TPU-friendly tile granularities.
LANE = 128      # last-dim tiling (VREG lane count, MXU edge)
SUBLANE = 8     # second-to-last dim granularity for f32


@functools.lru_cache(maxsize=None)
def use_interpret() -> bool:
    """Pallas interpret mode: forced via env, or implied off-TPU."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_axis(x: jnp.ndarray, axis: int, target: int, value=0) -> jnp.ndarray:
    """Pad ``axis`` of x up to length ``target`` with ``value``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads, constant_values=value)


def pick_tile(n: int, preferred: int, mult: int) -> int:
    """Largest multiple-of-``mult`` tile ≤ preferred that covers n sensibly."""
    if n <= mult:
        return mult
    t = min(preferred, ceil_to(n, mult))
    return max(mult, (t // mult) * mult)


# Storage dtype shims: Pallas TPU kernels operate on {f32, bf16, i32}; bools
# and narrow ints are widened at the wrapper boundary.
def widen_for_kernel(x: jnp.ndarray) -> tuple[jnp.ndarray, np.dtype]:
    orig = x.dtype
    if x.dtype == jnp.bool_:
        return x.astype(jnp.int32), orig
    if x.dtype in (jnp.int8, jnp.int16):
        return x.astype(jnp.int32), orig
    if x.dtype == jnp.float64:
        return x.astype(jnp.float32), orig
    return x, orig


def narrow_from_kernel(x: jnp.ndarray, orig: np.dtype) -> jnp.ndarray:
    if x.dtype != orig:
        return x.astype(orig)
    return x
