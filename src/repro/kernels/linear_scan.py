"""First-order linear recurrence kernel: h_t = a_t ⊙ h_{t-1} + b_t.

This is the shared primitive behind the recurrent architectures in the model
zoo — RG-LRU (RecurrentGemma) and the RWKV6 state update both reduce to
elementwise-gated linear recurrences.  Same blocked-scan structure as
``window_scan``: per-tile the recurrence is composed with an associative scan
over (a, b) pairs ((a2,b2)∘(a1,b1) = (a1·a2, a2·b1+b2)), and a (1, N) carry in
VMEM bridges tiles across the sequential grid.

Shapes: a, b — (T, N) (time-major, N = flattened state width, LANE-aligned by
the wrapper).  Returns all h_t, (T, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._util import LANE, SUBLANE, cdiv, ceil_to, pad_axis, pick_tile, use_interpret


def _compose(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def _linscan_kernel(a_ref, b_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # local inclusive scan of the recurrence within the tile (log-depth)
    acc_a, acc_b = jax.lax.associative_scan(_compose, (a, b), axis=0)
    # fold in the carry h_{tile-1}: h_t = acc_a_t * h_carry + acc_b_t
    h = acc_a * carry_ref[...] + acc_b
    carry_ref[...] = h[-1:, :]
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm",))
def _linear_scan_padded(a, b, tm: int):
    m, n = a.shape
    return pl.pallas_call(
        _linscan_kernel,
        grid=(cdiv(m, tm),),
        in_specs=[
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, n), jnp.float32)],
        interpret=use_interpret(),
    )(a, b)


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, *, tile_m: int = 512) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t along axis 0 (h_0 folded into b_0)."""
    assert a.shape == b.shape and a.ndim == 2, (a.shape, b.shape)
    m, n = a.shape
    if m == 0:
        return b.astype(jnp.float32)
    tm = pick_tile(m, tile_m, SUBLANE)
    npad = ceil_to(n, LANE)
    # pad a with 1s? a-padding only matters beyond m; rows past m are discarded
    ap = pad_axis(pad_axis(a.astype(jnp.float32), 0, ceil_to(m, tm)), 1, npad)
    bp = pad_axis(pad_axis(b.astype(jnp.float32), 0, ceil_to(m, tm)), 1, npad)
    return _linear_scan_padded(ap, bp, tm)[:m, :n]
