import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (EXPERIMENTS.md §Perf).

Compiles one (arch × shape) cell under a named *variant* — a set of config
overrides implementing a hypothesis — and records the full loop-corrected
HLO breakdown (top byte/flop contributors, wire bytes by collective kind) so
each hypothesis → change → measure cycle is one invocation:

  python -m repro.launch.hillclimb --arch rwkv6-1.6b --shape train_4k \
      --variant chunked --set rwkv_chunked=True

Results land in experiments/perf/<arch>__<shape>__<variant>.json.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

from ..configs import SHAPES, get_config  # noqa: E402
from .dryrun import (_decode_artifacts, _memory_dict, _model_flops,  # noqa: E402
                     _prefill_artifacts, _train_artifacts)
from .hlo_analysis import Roofline, analyze_hlo  # noqa: E402
from .mesh import dp_axes, make_production_mesh  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")


def _parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        elif v.isdigit():
            out[k] = int(v)
        else:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def _flash_adjust(stats, cfg, shape) -> dict:
    """Kernel-substitution accounting: the Pallas flash-attention kernel
    (validated vs oracle in tests) streams K/V tiles through VMEM and never
    writes the (chunk, S) probability matrices to HBM.  Subtract the
    *measured* bytes of exactly those tensors (identified by their
    (chunk=1024, S) trailing dims in the breakdown) and add the kernel's own
    HBM traffic (q,k,v read + o write per layer ≈ 4·tokens·H·Dh·2B — already
    counted via the projection dots, so the correction is pure removal)."""
    seq = shape.seq_len
    pat = f",{seq}]"
    chunk_tags = [f"1024,{seq}]", f"{seq},1024]", f"1024,{seq}]"]
    removed = 0.0
    for key, nbytes in stats.bytes_by_key.items():
        if any(t in key for t in chunk_tags):
            removed += nbytes
    return {"removed_bytes": removed,
            "hbm_bytes_fused_adj": stats.hbm_bytes_fused - removed}


def run_variant(arch: str, shape_name: str, variant: str, overrides: dict,
                multi_pod: bool = False, adjust: str = "") -> dict:
    cfg = dataclasses.replace(get_config(arch), **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    from . import sharding as shlib
    from ..models.sharding_ctx import activation_sharding
    t0 = time.monotonic()
    with activation_sharding(mesh, shlib.effective_dp(cfg, mesh)):
        if shape.kind == "train":
            lowered, _ = _train_artifacts(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered, _ = _prefill_artifacts(cfg, shape, mesh)
        else:
            lowered, _ = _decode_artifacts(cfg, shape, mesh)
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0

    repeats, _ = cfg.repeats_and_tail()
    stats = analyze_hlo(compiled.as_text(), default_trip=max(1, repeats))
    hbm = stats.hbm_bytes_fused
    wire = stats.wire_bytes
    adjustment = {}
    for adj in adjust.split(",") if adjust else []:
        if adj == "flash_attention":
            adjustment.update(_flash_adjust(stats, cfg, shape))
            hbm = adjustment["hbm_bytes_fused_adj"]
        elif adj == "bf16_psum":
            # XLA:CPU lowers bf16 dots as f32+convert, so GSPMD's partial-sum
            # all-reduces ride f32; a TPU compile reduces bf16.  Halve the
            # measured f32 collective payloads (activation cotangents/partials).
            adjustment["wire_bytes_adj"] = wire - 0.5 * stats.wire_bytes_f32
            wire = adjustment["wire_bytes_adj"]
    rl = Roofline(hlo_flops=stats.flops, hlo_bytes=hbm,
                  wire_bytes=wire, chips=chips,
                  model_flops=_model_flops(cfg, shape))
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "adjustment": adjustment,
        "compile_s": compile_s,
        "memory_analysis": _memory_dict(compiled),
        "hlo_analysis": stats.to_dict(),
        "roofline": rl.to_dict(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--adjust", default="")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    res = run_variant(args.arch, args.shape, args.variant,
                      _parse_overrides(args.set), args.multi,
                      adjust=args.adjust)
    path = os.path.join(OUT_DIR, f"{args.arch}__{args.shape}__{args.variant}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    rl = res["roofline"]
    print(f"{args.arch} {args.shape} [{args.variant}]  compile={res['compile_s']:.0f}s")
    print(f"  compute={rl['compute_s']:.3g}s memory={rl['memory_s']:.3g}s "
          f"collective={rl['collective_s']:.3g}s → {rl['bottleneck']}")
    print(f"  useful={rl['useful_flops_fraction']:.3f} "
          f"roofline_frac={rl['roofline_fraction']:.4f}")
    ha = res["hlo_analysis"]
    print("  wire by kind:", {k: f"{v:.3g}" for k, v in ha["wire_bytes_by_kind"].items()})
    print("  top bytes:")
    for k, v in ha["top_bytes"][:8]:
        print(f"    {v:12.3e}  {k}")
    print("  top flops:")
    for k, v in ha["top_flops"][:5]:
        print(f"    {v:12.3e}  {k}")


if __name__ == "__main__":
    main()
