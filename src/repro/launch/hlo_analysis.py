"""Compiled-HLO analysis: loop-corrected roofline terms.

XLA's ``compiled.cost_analysis()`` has two properties that break naive
roofline math (validated empirically in tests):
  1. numbers are **per-device** for GSPMD executables, and
  2. while-loop bodies are counted **once** — scan-over-layers, chunked
     attention (lax.map) and recurrent time-scans all live in while loops,
     so flops/bytes would be undercounted by 10–4000×.

This module therefore re-derives the three roofline terms from the optimized
HLO text itself:

  * computations are split and classified (entry / while body / fusion body /
    applier); while bodies get a trip-count multiplier parsed from their
    condition (``compare(..., constant(N))``), propagated through nesting;
  * FLOPs: every ``dot`` at fusion level — 2 × |result| × contracted dims
    (einsums/matmuls dominate compute on these models; elementwise flops are
    ignored, consistent with MFU conventions);
  * HBM bytes: per top-level instruction, result + operand bytes (post-fusion
    HLO means each fusion's operands/results are real HBM round-trips;
    parameter/tuple/GTE/bitcast plumbing is skipped);
  * collective wire bytes: all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute payloads with ring-algorithm factors
    (all-reduce 2×, reduce-scatter counts its input).

Everything is per-device; the Roofline dataclass turns the three totals into
seconds against TPU v5e peaks.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_OPS = ("parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "iota", "partition-id", "replica-id")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\s]*?))\s*([\w\-]+)\(")
_DOT_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, shape in _shape_list(text):
        total += int(np.prod(shape)) * _DTYPE_BYTES[dt] if shape else _DTYPE_BYTES[dt]
    return total


# -----------------------------------------------------------------------------
# computation splitting & loop-multiplier resolution
# -----------------------------------------------------------------------------
def _split_computations(hlo: str) -> tuple[dict[str, str], str | None]:
    comps: dict[str, str] = {}
    entry = None
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo.splitlines():
        if cur_name is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{", line)
            if m:
                cur_name = m.group(2)
                if m.group(1):
                    entry = cur_name
                cur_lines = [line]
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[cur_name] = line
                    cur_name = None
        else:
            cur_lines.append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
    return comps, entry


def _trip_count(cond_body: str) -> int | None:
    if "compare" not in cond_body:
        return None
    consts = [int(m.group(1)) for m in _CONST_RE.finditer(cond_body)]
    return max(consts) if consts else None


def _resolve_multipliers(comps: dict[str, str], entry: str | None,
                         default_trip: int) -> tuple[dict[str, float], int]:
    """comp name → execution multiplier (entry = 1; while bodies = trips,
    nested loops multiply).  Only entry + loop bodies/conds are 'live';
    fusion/applier computations are charged at their call sites."""
    mult: dict[str, float] = {}
    unresolved = 0
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {}, 0
    mult[entry] = 1.0
    work = [entry]
    seen = set()
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        body = comps.get(name, "")
        for m in _WHILE_RE.finditer(body):
            cond, loop_body = m.group(1), m.group(2)
            tc = _trip_count(comps.get(cond, ""))
            if tc is None:
                unresolved += 1
                tc = default_trip
            add = mult.get(name, 1.0) * tc
            mult[loop_body] = mult.get(loop_body, 0.0) + add
            work.append(loop_body)
    return mult, unresolved


# -----------------------------------------------------------------------------
# per-instruction accounting
# -----------------------------------------------------------------------------
@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # every top-level op's operands+results (raw)
    hbm_bytes_fused: float = 0.0  # TPU-fusion estimate: elementwise ops fuse
    wire_bytes: float = 0.0
    wire_bytes_f32: float = 0.0   # payloads XLA:CPU widened to f32 (TPU: bf16)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    wire_bytes_by_kind: dict = dataclasses.field(default_factory=dict)
    dot_count: float = 0.0
    unresolved_loops: int = 0
    # profiling breakdowns: (op, shape) → accumulated bytes / flops
    bytes_by_key: dict = dataclasses.field(default_factory=dict)
    flops_by_key: dict = dataclasses.field(default_factory=dict)

    def top_bytes(self, n: int = 15) -> list[tuple[str, float]]:
        return sorted(self.bytes_by_key.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n: int = 15) -> list[tuple[str, float]]:
        return sorted(self.flops_by_key.items(), key=lambda kv: -kv[1])[:n]

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_fused": self.hbm_bytes_fused,
            "wire_bytes": self.wire_bytes,
            "wire_bytes_f32": self.wire_bytes_f32,
            "collective_counts": self.collective_counts,
            "wire_bytes_by_kind": self.wire_bytes_by_kind,
            "dot_count": self.dot_count,
            "unresolved_loops": self.unresolved_loops,
            "top_bytes": self.top_bytes(),
            "top_flops": self.top_flops(),
        }


# Ops a TPU compile fuses into neighbors (XLA:CPU leaves many at top level,
# which would overstate HBM traffic ~10-40×): pure elementwise/shape plumbing.
_FUSES_AWAY = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "tanh", "rsqrt", "sqrt", "power", "negate", "abs", "sign", "compare",
    "select", "and", "or", "xor", "not", "convert", "broadcast", "reshape",
    "clamp", "floor", "ceil", "sine", "cosine", "is-finite", "reduce-precision",
    "exponential-minus-one", "log-plus-one", "logistic", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "map",
}


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"           # instruction name
    r"((?:\([^=]*?\))|(?:[\w\[\],{}\s]+?))\s*"         # result shape
    r"([\w\-]+)\(([^)]*)\)")                           # op + operand list
_OPND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_instructions(body: str):
    """Yield (name, result_shape_text, op, operand_names) per instruction."""
    for raw in body.splitlines()[1:]:
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, shape_text, op, opnds = m.groups()
        yield name, shape_text, op, _OPND_RE.findall(opnds), raw


def _symbol_table(body: str) -> dict[str, str]:
    """instruction name → result shape text (per-computation SSA scope)."""
    table = {}
    for name, shape_text, _op, _o, _raw in _parse_instructions(body):
        table[name] = shape_text
    return table


def analyze_hlo(hlo_text: str, default_trip: int = 1) -> HloStats:
    comps, entry = _split_computations(hlo_text)
    mult, unresolved = _resolve_multipliers(comps, entry, default_trip)
    stats = HloStats(unresolved_loops=unresolved)

    for cname, m in mult.items():
        body = comps.get(cname, "")
        table = _symbol_table(body)
        for name, shape_text, op, opnds, raw in _parse_instructions(body):
            if op in _SKIP_OPS or op == "while":
                continue
            result_bytes = _shape_bytes(shape_text)
            operand_bytes = sum(_shape_bytes(table.get(o, "")) for o in opnds)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                nbytes = result_bytes
                if base == "reduce-scatter":
                    nbytes = max(nbytes, operand_bytes)
                factor = 2.0 if base == "all-reduce" else 1.0
                stats.wire_bytes += factor * nbytes * m
                if "f32[" in shape_text:
                    stats.wire_bytes_f32 += factor * nbytes * m
                stats.wire_bytes_by_kind[base] = (
                    stats.wire_bytes_by_kind.get(base, 0.0) + factor * nbytes * m)
                stats.collective_counts[base] = (
                    stats.collective_counts.get(base, 0) + m)
                stats.hbm_bytes += (result_bytes + operand_bytes) * m
                key = f"{base} {shape_text.strip()[:48]}"
                stats.bytes_by_key[key] = (
                    stats.bytes_by_key.get(key, 0.0) + factor * nbytes * m)
                continue
            if op == "dot":
                res_shapes = _shape_list(shape_text)
                result_elems = int(np.prod(res_shapes[0][1])) if res_shapes and res_shapes[0][1] else 1
                contract = 1
                mcon = _DOT_LHS_CONTRACT_RE.search(raw)
                if mcon and opnds:
                    lhs_shapes = _shape_list(table.get(opnds[0], ""))
                    if lhs_shapes:
                        lhs_shape = lhs_shapes[0][1]
                        for d in mcon.group(1).split(","):
                            if d and int(d) < len(lhs_shape):
                                contract *= lhs_shape[int(d)]
                fl = 2.0 * result_elems * contract * m
                stats.flops += fl
                stats.dot_count += m
                fkey = f"dot {shape_text.strip()[:48]}"
                stats.flops_by_key[fkey] = stats.flops_by_key.get(fkey, 0.0) + fl
            stats.hbm_bytes += (result_bytes + operand_bytes) * m
            if op not in _FUSES_AWAY:
                stats.hbm_bytes_fused += (result_bytes + operand_bytes) * m
                bkey = f"{op} {shape_text.strip()[:48]}"
                stats.bytes_by_key[bkey] = (
                    stats.bytes_by_key.get(bkey, 0.0)
                    + (result_bytes + operand_bytes) * m)
    return stats


def xla_cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a dict (or a per-device list of dicts); newer jax
    returns a **list** with one entry for the executable.  Always hand back a
    plain dict (empty when XLA reports nothing) so callers can index by key.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


# Backwards-compatible shim for collective-only callers.
@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)
    unresolved_loops: int = 0


def collective_bytes(hlo_text: str, default_trip: int = 1) -> CollectiveStats:
    s = analyze_hlo(hlo_text, default_trip)
    return CollectiveStats(s.wire_bytes, s.collective_counts, s.unresolved_loops)


# -----------------------------------------------------------------------------
# roofline terms (TPU v5e)
# -----------------------------------------------------------------------------
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link (effective, per chip)


@dataclasses.dataclass
class Roofline:
    """All HLO quantities are **per-device** (GSPMD executables report the
    per-device module) and loop-corrected by ``analyze_hlo``.
    ``model_flops`` is global (6·N·D train / 2·N·D inference)."""

    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time bound (terms overlap: max, not sum)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        if not self.hlo_flops:
            return 0.0
        return (self.model_flops / self.chips) / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """(useful FLOPs/chip ÷ step-time bound) ÷ peak — the MFU the
        compiled program admits (1.0 ⇒ compute-bound, zero waste)."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.step_s) / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "wire_bytes_per_dev": self.wire_bytes,
            "chips": self.chips,
            "model_flops_global": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }
