"""Training entry point: ``python -m repro.launch.train --arch yi-6b --smoke``.

On this CPU container only ``--smoke`` (reduced config) actually executes;
full configs go through the dry-run.  The launcher wires together the
dataframe data pipeline, the trainer, checkpointing, and failure recovery —
the same objects a multi-host deployment would construct per process.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from ..configs import SHAPES, get_config, get_smoke_config
from ..data import DataPipeline, PipelineConfig, synthetic_corpus
from ..models import build_model
from ..train.fault import run_with_recovery
from ..train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--docs", type=int, default=4000)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not args.smoke and jax.default_backend() == "cpu":
        raise SystemExit("full configs need TPU; use --smoke on CPU "
                         "(the production mesh path is launch/dryrun.py)")

    model = build_model(cfg)
    pc = PipelineConfig(seq_len=args.seq_len, global_batch=args.batch,
                        memory_len=cfg.cross_memory_len, d_model=cfg.d_model)
    pipe = DataPipeline(synthetic_corpus(args.docs), cfg.vocab, pc)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     microbatches=args.microbatches,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=max(10, args.steps // 5))
    trainer = Trainer(model, tc)
    if args.checkpoint_dir:
        state = run_with_recovery(trainer, lambda: pipe.batches(), steps=args.steps)
    else:
        state = trainer.fit(pipe.batches(), steps=args.steps)

    print(json.dumps({"history": trainer.history[-5:],
                      "pipeline": pipe.stats()}, indent=1))


if __name__ == "__main__":
    main()
