import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove the sharding config is coherent, and extract the
roofline terms from the compiled artifact.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any other import so the 512 placeholder
host devices exist when jax first initializes.  Results are written
incrementally to ``experiments/dryrun/*.json`` so interrupted sweeps resume.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from ..models import build_model, input_specs  # noqa: E402
from ..models import model as model_lib  # noqa: E402
from ..train import optimizer as opt_lib  # noqa: E402
from ..train import schedule as sched_lib  # noqa: E402
from ..train.trainer import make_train_step  # noqa: E402
from . import sharding as shlib  # noqa: E402
from .hlo_analysis import (Roofline, analyze_hlo, collective_bytes,  # noqa: E402
                           xla_cost_analysis)
from .mesh import dp_axes, make_production_mesh  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# =============================================================================
# per-cell lowering
# =============================================================================
def _train_artifacts(cfg, shape, mesh):
    model = build_model(cfg)
    optimizer = opt_lib.get_optimizer(cfg.optimizer)
    lr_fn = sched_lib.warmup_cosine()

    params_sds = model_lib.params_specs(cfg)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    pspecs = shlib.param_specs(cfg, params_sds, mesh)
    ospecs = shlib.opt_state_specs(pspecs, params_sds, opt_sds)
    state_specs = {"params": pspecs, "opt": ospecs, "step": P()}

    grad_shardings = shlib.to_named(pspecs, mesh)
    step_fn = _raw_train_step(model, optimizer, lr_fn,
                              grad_shardings=grad_shardings)

    batch_sds = input_specs(cfg, shape)
    bspecs = shlib.batch_specs(cfg, shape, mesh, batch_sds)

    jitted = jax.jit(
        step_fn,
        in_shardings=(shlib.to_named(state_specs, mesh),
                      shlib.to_named(bspecs, mesh)),
        out_shardings=(shlib.to_named(state_specs, mesh), None),
        donate_argnums=(0,),
    )
    lowered = jitted.lower(state_sds, batch_sds)
    return lowered, {"state": (state_sds, state_specs), "batch": (batch_sds, bspecs)}


def _raw_train_step(model, optimizer, lr_fn, grad_shardings=None):
    """Full production step: microbatched grad accumulation (bounds live
    activation memory to one microbatch) + optimizer update.

    ``grad_shardings`` pins gradients (and therefore the accumulation
    buffers) to the parameter sharding: per-microbatch weight-grad partials
    reduce-scatter immediately instead of living replicated — without the
    pin, GSPMD keeps dW replicated over the FSDP axis and the accumulator
    read/write traffic multiplies by the DP degree."""
    mb = max(1, model.cfg.train_microbatches)

    def pin(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def step(state, batch):
        params = state["params"]
        if mb > 1:
            def reshape(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def acc(carry, one):
                loss_sum, grad_sum = carry
                (loss, _), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, one)
                grads = pin(grads)
                return (loss_sum + loss,
                        pin(jax.tree.map(jnp.add, grad_sum, grads))), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), micro)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            grads = pin(grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = optimizer.update(grads, state["opt"], params, lr)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1},
                {"loss": loss})
    return step


def _prefill_artifacts(cfg, shape, mesh):
    model = build_model(cfg)
    params_sds = model_lib.params_specs(cfg)
    pspecs = shlib.param_specs(cfg, params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    bspecs = shlib.batch_specs(cfg, shape, mesh, batch_sds)

    def serve_prefill(params, batch):
        return model.prefill(params, batch["tokens"], batch.get("memory"))

    jitted = jax.jit(serve_prefill,
                     in_shardings=(shlib.to_named(pspecs, mesh),
                                   shlib.to_named(bspecs, mesh)),
                     out_shardings=None)
    lowered = jitted.lower(params_sds, batch_sds)
    return lowered, {"params": (params_sds, pspecs), "batch": (batch_sds, bspecs)}


def _decode_artifacts(cfg, shape, mesh):
    model = build_model(cfg)
    params_sds = model_lib.params_specs(cfg)
    pspecs = shlib.param_specs(cfg, params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    bspecs = shlib.batch_specs(cfg, shape, mesh, batch_sds)
    cache_sds = model_lib.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cspecs = shlib.cache_specs_tree(cfg, shape, mesh, cache_sds)

    def serve_step(params, batch, cache):
        return model.decode_step(params, batch["token"], cache,
                                 batch.get("memory"))

    jitted = jax.jit(serve_step,
                     in_shardings=(shlib.to_named(pspecs, mesh),
                                   shlib.to_named(bspecs, mesh),
                                   shlib.to_named(cspecs, mesh)),
                     out_shardings=(None, shlib.to_named(cspecs, mesh)),
                     donate_argnums=(2,))
    lowered = jitted.lower(params_sds, batch_sds, cache_sds)
    return lowered, {"params": (params_sds, pspecs), "batch": (batch_sds, bspecs),
                     "cache": (cache_sds, cspecs)}


# =============================================================================
# analyses
# =============================================================================
def _cost_dict(compiled) -> dict:
    try:
        ca = xla_cost_analysis(compiled)   # list/dict normalized across jax versions
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and np.isfinite(float(v))}
    except Exception as e:
        return {"error": str(e)}


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
        if not out:
            out["repr"] = str(ma)
    except Exception as e:
        out["error"] = str(e)
    return out


def _sharded_arg_bytes(sds_specs: dict, mesh) -> dict:
    """Per-device bytes of each argument group under its PartitionSpec."""
    sizes = {}
    for group, (sds, specs) in sds_specs.items():
        total = 0
        flat_s = jax.tree.leaves(sds)
        flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for s, spec in zip(flat_s, flat_p):
            nbytes = int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize if s.shape else jnp.dtype(s.dtype).itemsize
            denom = 1
            for entry in tuple(spec):
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    denom *= mesh.shape[ax]
            total += nbytes // max(1, denom)
        sizes[group] = total
    return sizes


def _model_flops(cfg, shape) -> float:
    _, active = cfg.param_count()
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch          # decode: one token/seq


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             skip_compile: bool = False) -> dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    t0 = time.monotonic()
    from ..models.sharding_ctx import activation_sharding
    with activation_sharding(mesh, shlib.effective_dp(cfg, mesh)):
        if shape.kind == "train":
            lowered, groups = _train_artifacts(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered, groups = _prefill_artifacts(cfg, shape, mesh)
        else:
            lowered, groups = _decode_artifacts(cfg, shape, mesh)
    lower_s = time.monotonic() - t0

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "status": "lowered", "lower_s": lower_s,
        "arg_bytes_per_device": _sharded_arg_bytes(groups, mesh),
    }
    if skip_compile:
        return result

    t1 = time.monotonic()
    compiled = lowered.compile()
    result["compile_s"] = time.monotonic() - t1
    result["status"] = "compiled"
    result["memory_analysis"] = _memory_dict(compiled)
    result["cost_analysis_raw"] = _cost_dict(compiled)   # loops-once, per-dev

    hlo = compiled.as_text()
    repeats, _ = cfg.repeats_and_tail()
    stats = analyze_hlo(hlo, default_trip=max(1, repeats))
    result["hlo_analysis"] = stats.to_dict()
    rl = Roofline(
        hlo_flops=stats.flops,
        hlo_bytes=stats.hbm_bytes_fused,   # TPU-fusion estimate (raw recorded too)
        wire_bytes=stats.wire_bytes,
        chips=chips,
        model_flops=_model_flops(cfg, shape),
    )
    result["roofline"] = rl.to_dict()
    result["roofline"]["hlo_bytes_raw_per_dev"] = stats.hbm_bytes
    return result


# =============================================================================
# the dataframe-pipeline dry-run (the paper's technique on the mesh)
# =============================================================================
def run_pipeline_cell(multi_pod: bool, rows: int = 1 << 22, cols: int = 256,
                      groups: int = 8) -> dict:
    """Lower the Fig.-6 operator mix (map + groupby(n) + groupby(1) + window)
    as one shard_map program over the production mesh: rows shard DP, columns
    shard "model"; the groupby combine is the psum the paper's shuffle became."""
    from jax.experimental.shard_map import shard_map

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    dp = dp_axes(mesh)

    vals = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    codes = jax.ShapeDtypeStruct((rows,), jnp.int32)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp, "model"), P(dp)),
        out_specs=(P(dp, "model"), P(None, "model"), P(None, "model"),
                   P(dp, "model")),
        check_rep=False)
    def pipeline_step(v, c):
        # MAP: null-scrub (paper's map benchmark: isnull→fill)
        mapped = jnp.where(jnp.isnan(v), 0.0, v)
        # GROUPBY(n): local MXU one-hot partial + psum over the DP axes
        onehot = jax.nn.one_hot(c % groups, groups, dtype=jnp.float32)
        partial = jnp.einsum("rg,rc->gc", onehot, mapped)
        gb_n = jax.lax.psum(partial, dp)
        # GROUPBY(1): plain reduction
        gb_1 = jax.lax.psum(mapped.sum(axis=0, keepdims=True), dp)
        # WINDOW: local cumsum + exclusive cross-shard carry (order-exact)
        local = jnp.cumsum(mapped, axis=0)
        totals = jax.lax.all_gather(local[-1], dp, tiled=False)
        idx = jax.lax.axis_index(dp[0]) if len(dp) == 1 else (
            jax.lax.axis_index(dp[0]) * mesh.shape[dp[1]] + jax.lax.axis_index(dp[1]))
        nshards = totals.shape[0]
        mask = (jnp.arange(nshards) < idx).astype(jnp.float32)
        carry = jnp.einsum("s,sc->c", mask, totals)
        window = local + carry
        return mapped, gb_n, gb_1, window

    t0 = time.monotonic()
    lowered = jax.jit(pipeline_step).lower(vals, codes)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    stats = collective_bytes(compiled.as_text())
    return {
        "arch": "dataframe-pipeline", "shape": f"rows{rows}_cols{cols}",
        "mesh": "multi" if multi_pod else "single", "chips": chips,
        "status": "compiled", "compile_s": time.monotonic() - t0,
        "cost_analysis": cost,
        "collectives": {"wire_bytes": stats.wire_bytes, "counts": stats.counts},
        "memory_analysis": _memory_dict(compiled),
    }


# =============================================================================
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="also dry-run the dataframe pipeline step")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else tuple(args.arch.split(","))
    shapes = tuple(SHAPES) if args.shape == "all" else tuple(args.shape.split(","))
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag}", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"[done] {tag}: {res['status']}", flush=True)

    if args.pipeline:
        for mp in meshes:
            tag = f"pipeline__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                continue
            res = run_pipeline_cell(mp)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"[done] {tag}: {res['status']}", flush=True)


if __name__ == "__main__":
    main()
