"""Serving entry point: ``python -m repro.launch.serve --arch yi-6b --smoke``.

Batched greedy decoding over synthetic requests with the continuous-batching
engine; full-config serving paths are exercised by the decode/prefill cells
of ``launch/dryrun.py``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from ..configs import get_config, get_smoke_config
from ..data.tokenizer import HashTokenizer
from ..models import build_model
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not args.smoke and jax.default_backend() == "cpu":
        raise SystemExit("full configs need TPU; use --smoke on CPU")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_seq=args.max_seq)
    tok = HashTokenizer(cfg.vocab)
    prompts = [f"request number {i} about dataframes" for i in range(args.requests)]
    reqs = [Request(rid=i, prompt_ids=tok.encode(p), max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    t0 = time.monotonic()
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    dt = time.monotonic() - t0
    out = dict(engine.metrics)
    out["wall_s"] = dt
    out["tokens_per_s"] = engine.metrics["tokens_out"] / dt if dt else 0
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
