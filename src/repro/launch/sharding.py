"""Sharding policies: param/optimizer/batch/cache PartitionSpecs per
(architecture × shape × mesh).

Parallelism (DESIGN.md §5):
  * TP  — Megatron pairing: column-parallel in-projections P(None, "model"),
    row-parallel out-projections P("model", None) ⇒ two psums per block.
  * DP  — batch over ("pod", "data"); gradients reduce over DP axes.
  * FSDP — for params-too-big-for-TP archs, weights also shard the non-TP
    dim over "data" (all-gather at use; ZeRO-3-style).
  * EP  — MoE expert dim over "model"; token routing becomes an all-to-all.
  * SP  — decode KV caches shard the *sequence* dim (flash-decode style);
    batch dim shards DP when divisible.

Only inputs/params are annotated; intermediate shardings are propagated by
GSPMD.  Every rule degrades to None when a dim isn't divisible by the axis.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from .mesh import axis_size, dp_axes

FSDP_THRESHOLD_BYTES = 2 << 30    # params/chip beyond this → FSDP over "data"


def _div(size: int, mesh, axes) -> bool:
    return axes is not None and size % axis_size(mesh, axes) == 0 and size > 0


def _maybe(size: int, mesh, axes):
    """axes if divisible else None."""
    if axes is None:
        return None
    ax = axes if isinstance(axes, tuple) else (axes,)
    return axes if _div(size, mesh, ax) else None


def use_fsdp(cfg: ArchConfig, mesh) -> bool:
    total, _ = cfg.param_count()
    bytes_per_chip_tp = total * 2 / mesh.shape["model"]
    return bytes_per_chip_tp > FSDP_THRESHOLD_BYTES


def effective_dp(cfg: ArchConfig, mesh) -> tuple[str, ...]:
    """Axes the batch shards over.  Pure-FSDP mode has no TP, so the "model"
    axis joins data parallelism (otherwise it would sit idle)."""
    base = dp_axes(mesh)
    if cfg.sharding_mode == "fsdp":
        return base + ("model",)
    return base


# -----------------------------------------------------------------------------
# parameter specs (structural walk over the param tree)
# -----------------------------------------------------------------------------
def param_specs(cfg: ArchConfig, params_shapes: Any, mesh) -> Any:
    """PartitionSpec tree matching the params pytree (by path patterns).

    sharding_mode:
      * "tp"   — Megatron TP over "model" only;
      * "fsdp" — no TP: every ≥2-D weight shards dim 0 over "data" (ZeRO-3;
        all-gathered at use).  Right for small models where TP collectives
        dominate (per-shard matmuls too skinny);
      * "auto" — TP, plus FSDP over "data" when TP-sharded params exceed
        per-chip HBM budget (the big archs).
    """
    mode = cfg.sharding_mode
    if mode == "fsdp":
        return _fsdp_only_specs(params_shapes, mesh)
    fsdp = mode != "tp" and use_fsdp(cfg, mesh)
    fsdp_ax = "data" if fsdp else None

    def spec_for(path: tuple, shape: tuple) -> P:
        names = [p for p in path]
        name = names[-1] if names else ""
        stacked = "blocks" in names  # scanned: leading repeats dim
        lead = (None,) if stacked else ()

        def col(io_shape):  # (in, out) column-parallel
            return P(*lead, _maybe(io_shape[0], mesh, fsdp_ax),
                     _maybe(io_shape[1], mesh, "model"))

        def row(io_shape):  # (in, out) row-parallel
            return P(*lead, _maybe(io_shape[0], mesh, "model"),
                     _maybe(io_shape[1], mesh, fsdp_ax))

        body = shape[1:] if stacked else shape
        # ---- embeddings ----------------------------------------------------
        if name in ("embed", "unembed"):
            return P(_maybe(shape[0], mesh, "model"),
                     _maybe(shape[1], mesh, fsdp_ax))
        # ---- MoE (E, in, out): expert-parallel over "model" ---------------
        if name in ("w_gate", "w_up", "w_down") and len(body) == 3:
            return P(*lead, _maybe(body[0], mesh, "model"), None,
                     _maybe(body[2], mesh, fsdp_ax))
        if name == "router":
            return P(*lead, None, None)
        # ---- attention -----------------------------------------------------
        if name in ("wq", "wk", "wv") and len(body) == 2:
            return col(body)
        if name == "wo" and len(body) == 2:
            return row(body)
        # ---- dense MLPs ------------------------------------------------------
        if name in ("w_gate", "w_up", "w_k"):   # column side
            return col(body) if len(body) == 2 else P(*lead, *(None,) * len(body))
        if name in ("w_down", "w_v"):
            return row(body) if len(body) == 2 else P(*lead, *(None,) * len(body))
        # ---- recurrent blocks ------------------------------------------------
        if name in ("w_x", "w_gate_branch", "w_input_gate", "w_rec_gate",
                    "w_r", "w_g"):
            return col(body) if len(body) == 2 else P(*lead, *(None,) * len(body))
        if name in ("w_out", "w_o"):
            return row(body) if len(body) == 2 else P(*lead, *(None,) * len(body))
        # ---- everything else (norms, biases, small tensors): replicate ----
        return P(*lead, *(None,) * len(body))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        names = tuple(_path_name(p) for p in path)
        specs.append(spec_for(names, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(jax.tree.structure(params_shapes), specs)


def _path_name(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _fsdp_only_specs(params_shapes: Any, mesh) -> Any:
    """Pure ZeRO-3: shard a dim of every weight over the *flattened*
    ("data","model") axes; no tensor parallelism (weights all-gather
    just-in-time; the batch shards over both axes too)."""
    all_axes = tuple(a for a in mesh.axis_names)

    def spec_for(path, shape) -> P:
        names = [_path_name(p) for p in path]
        stacked = "blocks" in names
        body = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()
        if len(body) < 2:
            return P(*lead, *(None,) * len(body))
        entries: list = [None] * len(body)
        for d in range(len(body)):          # prefer dim0; degrade by divisibility
            if _div(body[d], mesh, all_axes):
                entries[d] = all_axes
                break
            if _div(body[d], mesh, ("data",)):
                entries[d] = "data"
                break
        return P(*lead, *entries)

    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    specs = [spec_for(path, tuple(leaf.shape)) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(jax.tree.structure(params_shapes), specs)


# -----------------------------------------------------------------------------
# optimizer-state specs (shape-matched against the param spec)
# -----------------------------------------------------------------------------
def opt_state_specs(param_specs_tree: Any, params_shapes: Any, state_shapes: Any) -> Any:
    """Derive state specs: exact-shape leaves inherit the param spec; factored
    adafactor moments drop the reduced dim's spec entry; scalars replicate."""
    flat_params = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    flat_specs = jax.tree.leaves(param_specs_tree)
    by_path = {}
    for (path, leaf), spec in zip(flat_params, flat_specs):
        by_path[tuple(_path_name(x) for x in path)] = (tuple(leaf.shape), spec)

    def spec_for_state(path: tuple, shape: tuple):
        # state paths look like ("m", *param_path) / ("v", *param_path, "vr")
        for start in range(len(path)):
            for end in range(len(path), start, -1):
                key = path[start:end]
                if key in by_path:
                    pshape, pspec = by_path[key]
                    if shape == pshape:
                        return pspec
                    if shape == pshape[:-1]:           # adafactor vr
                        return P(*tuple(pspec)[:-1])
                    if shape == pshape[:-2] + pshape[-1:]:  # adafactor vc
                        return P(*(tuple(pspec)[:-2] + tuple(pspec)[-1:]))
        return P(*(None,) * len(shape))

    flat_state = jax.tree_util.tree_flatten_with_path(state_shapes)[0]
    specs = [spec_for_state(tuple(_path_name(x) for x in path), tuple(l.shape))
             for path, l in flat_state]
    return jax.tree_util.tree_unflatten(jax.tree.structure(state_shapes), specs)


# -----------------------------------------------------------------------------
# batch / cache specs
# -----------------------------------------------------------------------------
def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                specs_tree: Any) -> Any:
    dp = effective_dp(cfg, mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b = shape.global_batch

    def spec_for(path, leaf):
        name = _path_name(path[-1])
        bshard = _maybe(b, mesh, dp)
        if name in ("tokens", "labels", "mask"):
            return P(bshard, None)
        if name == "token":
            return P(bshard)
        if name == "memory":
            return P(bshard, None, None)
        return P(*(None,) * len(leaf.shape))

    flat = jax.tree_util.tree_flatten_with_path(specs_tree)[0]
    out = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(jax.tree.structure(specs_tree), out)


def cache_specs_tree(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     cache_shapes: Any) -> Any:
    """KV/state cache sharding: batch → DP when divisible, sequence dim →
    "model" (+ "data" when batch is unshardable) — flash-decode SP."""
    dp = effective_dp(cfg, mesh) if cfg.sharding_mode == "fsdp" else dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b = shape.global_batch
    b_ok = _div(b, mesh, dp if isinstance(dp, tuple) else (dp,))
    seq_ax = "model" if b_ok else (dp + ("model",) if isinstance(dp, tuple)
                                   else (dp, "model"))

    def spec_for(path, leaf):
        names = [_path_name(x) for x in path]
        name = names[-1]
        stacked = "blocks" in names
        lead = (None,) if stacked else ()
        body = leaf.shape[1:] if stacked else leaf.shape
        if name == "length":
            return P(None)
        if name in ("k", "v") and len(body) == 4:      # (B, S, K, Dh)
            return P(*lead, _maybe(b, mesh, dp), _maybe(body[1], mesh, seq_ax),
                     None, None)
        if name == "S" and len(body) == 4:             # rwkv (B, H, dk, dv)
            return P(*lead, _maybe(b, mesh, dp),
                     _maybe(body[1], mesh, "model"), None, None)
        if name == "h" and len(body) == 2:             # rglru (B, dr)
            return P(*lead, _maybe(b, mesh, dp), _maybe(body[1], mesh, "model"))
        if len(body) >= 1:
            return P(*lead, _maybe(body[0], mesh, dp),
                     *(None,) * (len(body) - 1))
        return P(*lead)

    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)[0]
    out = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(jax.tree.structure(cache_shapes), out)


def to_named(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
