"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this process actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh ("pod" folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, axes) -> int:
    size = 1
    for a in ([axes] if isinstance(axes, str) else axes):
        size *= mesh.shape[a]
    return size
