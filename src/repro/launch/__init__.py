"""Launch layer: production mesh, sharding policies, multi-pod dry-run,
train/serve entry points.  NOTE: ``dryrun`` must be run as its own process
(it sets XLA_FLAGS before jax init); do not import it from a live session.
"""
from .mesh import make_local_mesh, make_production_mesh  # noqa: F401
