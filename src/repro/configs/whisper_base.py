"""whisper-base [audio] — 6L enc + 6L dec, d512 8H ff2048 vocab 51865.
Encoder–decoder; conv audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d).  Assigned shapes are honored
mechanically on the decoder (real whisper caps decoder context at 448 —
noted in DESIGN.md).  [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                  # decoder depth
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    pattern=("dec",),
    mlp="gelu",
    norm="layernorm",
    use_rope=False,              # sinusoidal positions
    encoder_layers=6,
    cross_memory_len=1500,       # 30 s of audio at 50 Hz after the conv stub
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=256, encoder_layers=2, cross_memory_len=16)
