"""yi-6b [dense] — 32L d4096 32H (GQA kv=4) ff11008 vocab 64000.
Llama-architecture GQA.  [arXiv:2403.04652; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    pattern=("attn",),
    mlp="swiglu",
    train_microbatches=2,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)
