"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) expert-ff 512
vocab 49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv=8,
    d_ff=512,
    vocab=49155,
    pattern=("attn",),
    mlp="moe",
    n_experts=40,
    top_k=8,
    tie_embeddings=True,          # granite MoE ties embeddings
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv=2, head_dim=12,
        d_ff=64, vocab=128, n_experts=5, top_k=2)
