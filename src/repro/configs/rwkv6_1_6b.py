"""rwkv6-1.6b (Finch) [ssm] — 24L d2048 attention-free, cmix-ff 7168,
vocab 65536.  Data-dependent decay time-mixing + channel mixing.
[arXiv:2404.05892]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # heads = d_model / rwkv_head_dim
    n_kv=32,
    d_ff=7168,
    vocab=65536,
    pattern=("rwkv",),
    mlp="rwkv_cmix",
    rwkv_head_dim=64,
    use_rope=False,
    norm="layernorm",
    sub_quadratic=True,
    tie_embeddings=False,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=96,
        vocab=256, rwkv_head_dim=16)
