"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) expert-ff 1536
vocab 151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,                 # qwen3 uses explicit head_dim 128
    pattern=("attn",),
    mlp="moe",
    n_experts=128,
    top_k=8,
    optimizer="adafactor",        # AdamW f32 states don't fit 235B on 256 chips
    attn_impl="auto",
    train_microbatches=8,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=96, vocab=256, n_experts=8, top_k=2)
