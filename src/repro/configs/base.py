"""Architecture config schema + input-shape sets.

One ``ArchConfig`` per assigned architecture lives in a sibling module; each
exposes ``CONFIG`` (full size, dry-run only) and ``smoke_config()`` (reduced,
CPU-runnable).  ``repro.configs.registry`` maps ``--arch <id>`` to them.

``pattern`` describes the repeating layer superblock; a stack is
``n_layers // len(pattern)`` scanned repeats plus an unrolled tail.
Block kinds: attn (global causal) · local (windowed causal) · cross
(attends to modality memory) · rglru (Griffin recurrent) · rwkv (RWKV6
time-mix; pairs with channel-mix MLP) · dec (whisper decoder layer:
self-attn + cross-attn + MLP).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads
    qkv_bias: bool = False
    pattern: tuple = ("attn",)
    window: Optional[int] = None     # local-attention span
    mlp: str = "swiglu"              # swiglu | gelu | moe | rwkv_cmix
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    use_rope: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 16             # group-local dispatch (GShard groups)
    # modality stubs
    cross_memory_len: int = 0        # vlm patch / whisper frame count
    encoder_layers: int = 0          # whisper encoder depth
    # recurrent
    rnn_width: int = 0               # rglru (0 ⇒ d_model)
    rwkv_head_dim: int = 64
    rwkv_chunked: bool = False       # chunked linear-attention path (§Perf)
    # execution
    attn_impl: str = "auto"          # auto | xla | chunked | pallas
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs:
                                     # no fwd-psum re-execution in bwd)
    scan_layers: bool = True         # False ⇒ python-loop (probe compiles)
    train_microbatches: int = 1      # grad-accumulation chunks per step
    sharding_mode: str = "auto"      # auto | tp | fsdp  (weight layout policy)
    optimizer: str = "adamw"         # adamw | adafactor
    tie_embeddings: bool = False
    sub_quadratic: bool = False      # eligible for long_500k
    param_dtype: str = "bfloat16"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def rnn_width_(self) -> int:
        return self.rnn_width or self.d_model

    def repeats_and_tail(self) -> tuple[int, int]:
        p = len(self.pattern)
        return self.n_layers // p, self.n_layers % p

    # ---- analytic parameter counts (roofline MODEL_FLOPS) -----------------
    def _block_params(self, kind: str) -> tuple[int, int]:
        """(total, active-per-token) parameters of one block of ``kind``."""
        d, hd = self.d_model, self.head_dim_
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv) * hd
        if self.mlp == "moe":
            mlp = self.n_experts * (3 * d * self.d_ff) + d * self.n_experts
            mlp_active = self.top_k * (3 * d * self.d_ff) + d * self.n_experts
        elif self.mlp == "gelu":
            mlp = mlp_active = 2 * d * self.d_ff + self.d_ff + d
        elif self.mlp == "rwkv_cmix":
            mlp = mlp_active = d * self.d_ff * 2 + d * d
        else:
            mlp = mlp_active = 3 * d * self.d_ff
        norms = 2 * d
        if kind in ("attn", "local", "cross"):
            core = attn
        elif kind == "dec":
            core = 2 * attn
            norms = 3 * d
        elif kind == "rglru":
            dr = self.rnn_width_
            core = 2 * d * dr + 2 * dr * dr + 4 * dr + dr * d
        else:  # rwkv time-mix
            core = 5 * d * d + d * 64 + 5 * d
        return core + mlp + norms, core + mlp_active + norms

    def param_count(self) -> tuple[int, int]:
        """(total, active-per-token) parameter counts."""
        total = active = 0
        for li in range(self.n_layers):
            kind = self.pattern[li % len(self.pattern)]
            t, a = self._block_params(kind)
            total += t
            active += a
        emb = self.vocab * self.d_model
        total += emb + self.d_model
        active += emb + self.d_model
        if not self.tie_embeddings:
            total += emb
            active += emb
        if self.encoder_layers:
            enc_t, _ = self._block_params("attn")
            total += self.encoder_layers * enc_t
            active += self.encoder_layers * enc_t
        return total, active


# -----------------------------------------------------------------------------
# the assigned input-shape sets (LM family)
# -----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) — see DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense KV per layer out of scope"
    return True, ""
