"""``--arch <id>`` registry: all 10 assigned architectures."""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable  # noqa: F401

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma3-12b": "gemma3_12b",
    "yi-6b": "yi_6b",
    "granite-8b": "granite_8b",
    "whisper-base": "whisper_base",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()
