"""gemma3-12b [dense] — 48L d3840 16H (GQA kv=8) ff15360 vocab 262144.
5:1 local:global attention, 128k context; local window 1024.
[hf:google/gemma-3-1b-pt; unverified]

Runs ``long_500k``: decode cost is O(window) on the 40 local layers and
O(S) only on the 8 global layers — sub-quadratic in aggregate (see
DESIGN.md §Arch-applicability for the global-layer KV caveat)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    mlp="swiglu",                # gemma uses GeGLU; swiglu stands in
    tie_embeddings=True,
    sub_quadratic=True,
    optimizer="adafactor",       # 262k-vocab embedding
    train_microbatches=4,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, window=8)
