"""qwen1.5-4b [dense] — 40L d2560 20H (kv=20, MHA) ff6912 vocab 151936,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    pattern=("attn",),
    mlp="swiglu",
    train_microbatches=2,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256)
