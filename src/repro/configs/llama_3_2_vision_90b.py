"""llama-3.2-vision-90b [vlm] — 100L d8192 64H (GQA kv=8) ff28672 vocab 128256.
Cross-attention image layers every 5th layer; vision frontend is a STUB
(``input_specs()`` provides precomputed patch embeddings (B, 1600, d)).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28672,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    mlp="swiglu",
    cross_memory_len=1600,
    optimizer="adafactor",
    train_microbatches=8,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, cross_memory_len=16)
