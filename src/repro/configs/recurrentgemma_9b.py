"""recurrentgemma-9b [hybrid] — 38L d4096 16H (GQA kv=1, MQA) ff12288
vocab 256000.  RG-LRU + local attention, 1 attention per 2 recurrent
(Griffin pattern rec,rec,attn); local window 2048.  [arXiv:2402.19427]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,                   # 12×(rglru,rglru,local) + (rglru, rglru)
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    mlp="gelu",                    # Griffin uses GeGLU; gelu-MLP stands in
    rnn_width=4096,
    sub_quadratic=True,
    tie_embeddings=True,
    optimizer="adafactor",         # 256k vocab embedding dominates state
    train_microbatches=4,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=256, rnn_width=64, window=8)
