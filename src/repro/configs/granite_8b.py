"""granite-8b [dense] — 36L d4096 32H (GQA kv=8) ff14336 vocab 49152.
Llama-architecture, code model.  [arXiv:2405.04324; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=49152,
    pattern=("attn",),
    mlp="swiglu",
    train_microbatches=2,
)


def smoke_config() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256)
