"""repro: *Towards Scalable Dataframe Systems* (Petersohn et al., 2020) on
JAX/TPU — a Modin-style partitioned dataframe system (core/), Pallas kernels
for its hot operators (kernels/), and the LM training/serving substrate that
the assigned architectures × shapes run on (models/, train/, serve/,
launch/), with the dataframe system as the data pipeline (data/).
"""
__version__ = "0.1.0"
