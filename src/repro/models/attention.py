"""Attention: GQA self-attention (RoPE, optional QKV bias, optional local
window), cross-attention, and KV-cache decode.

Three execution paths, selected by ``impl``:
  * "xla"     — einsum attention with explicit masks; fine for short S.
  * "chunked" — query-chunked attention (lax.map over chunks): never
                materializes S×S, the XLA analogue of flash attention; used
                for long-context prefill in the dry-run path.
  * "pallas"  — the fused flash kernel (TPU); validated in interpret mode.

Shapes: x (B, S, d); params store fused qkv projections (d, (H+2K)·Dh).
KV caches are (B, S_max, K, Dh) with a scalar per-example length.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from ..kernels import ops as kops

NEG = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False, dtype=layers.DEFAULT_PARAM_DTYPE) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(kq, d_model, n_heads * head_dim, dtype),
        "wk": layers.dense_init(kk, d_model, n_kv * head_dim, dtype),
        "wv": layers.dense_init(kv, d_model, n_kv * head_dim, dtype),
        "wo": layers.dense_init(ko, n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim, positions, use_rope=True):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    if use_rope:
        q = layers.rope(q, positions)
        k = layers.rope(k, positions)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


# -----------------------------------------------------------------------------
# core attention (three paths)
# -----------------------------------------------------------------------------
def _xla_attention(q, k, v, *, causal: bool, window: int | None,
                   kv_len: jnp.ndarray | None = None):
    """q (B,Sq,H,D); k/v (B,Sk,H,D) — full-mask einsum path (f32 softmax)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    m = mask[None, None]
    if kv_len is not None:
        m = m & (kpos[None, None] < kv_len[:, None, None, None])
    logits = jnp.where(m, logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, window: int | None,
                       chunk: int = 1024, kv_len=None):
    """Query-chunked attention: O(chunk·Sk) live memory, never S×S."""
    b, sq, h, d = q.shape
    if sq <= chunk:
        return _xla_attention(q, k, v, causal=causal, window=window, kv_len=kv_len)
    pad = (-sq) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = qp.shape[1] // chunk
    qc = qp.reshape(b, nchunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    sk = k.shape[1]

    def one(ci_q):
        ci, qi = ci_q
        # positions of this chunk within the full query range
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                            k.astype(jnp.float32)) / np.sqrt(d)
        qpos = ci * chunk + jnp.arange(chunk)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        mask = jnp.ones((chunk, sk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        m = mask[None, None]
        if kv_len is not None:
            m = m & (kpos[None, None] < kv_len[:, None, None, None])
        logits = jnp.where(m, logits, NEG)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)

    outs = jax.lax.map(one, (jnp.arange(nchunks), qc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, h, d)
    return out[:, :sq]


def _pallas_attention(q, k, v, *, causal: bool, window: int | None):
    b, sq, h, d = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    out = kops.flash_attention(qf, kf, vf, causal=causal, window=window)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def multihead_attention(q, k, v, *, causal=True, window=None, impl="xla",
                        kv_len=None):
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,D) — GQA-expands kv then dispatches."""
    groups = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, groups), _repeat_kv(v, groups)
    if impl == "pallas" and kv_len is None:
        return _pallas_attention(q, k, v, causal=causal, window=window)
    if impl == "chunked":
        return _chunked_attention(q, k, v, causal=causal, window=window, kv_len=kv_len)
    return _xla_attention(q, k, v, causal=causal, window=window, kv_len=kv_len)


# -----------------------------------------------------------------------------
# block-level entry points
# -----------------------------------------------------------------------------
def self_attention(params: dict, x: jnp.ndarray, *, n_heads: int, n_kv: int,
                   head_dim: int, causal: bool = True, window: int | None = None,
                   impl: str = "xla", positions: jnp.ndarray | None = None,
                   use_rope: bool = True) -> jnp.ndarray:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, use_rope)
    out = multihead_attention(q, k, v, causal=causal, window=window, impl=impl)
    return jnp.einsum("bsh,he->bse", out.reshape(b, s, n_heads * head_dim),
                      params["wo"], preferred_element_type=x.dtype)


def cross_attention(params: dict, x: jnp.ndarray, memory: jnp.ndarray, *,
                    n_heads: int, n_kv: int, head_dim: int, impl: str = "xla") -> jnp.ndarray:
    """x (B,S,d) attends to memory (B,M,d) — VLM image layers / enc-dec."""
    b, s, _ = x.shape
    m = memory.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(b, s, n_heads, head_dim)
    k = jnp.einsum("bmd,dh->bmh", memory, params["wk"]).reshape(b, m, n_kv, head_dim)
    v = jnp.einsum("bmd,dh->bmh", memory, params["wv"]).reshape(b, m, n_kv, head_dim)
    out = multihead_attention(q, k, v, causal=False, impl=impl)
    return jnp.einsum("bsh,he->bse", out.reshape(b, s, n_heads * head_dim),
                      params["wo"], preferred_element_type=x.dtype)


# -----------------------------------------------------------------------------
# KV cache (decode path)
# -----------------------------------------------------------------------------
def cache_init(batch: int, s_max: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
    }


def decode_self_attention(params: dict, x: jnp.ndarray, cache: dict,
                          length: jnp.ndarray, *, n_heads: int, n_kv: int,
                          head_dim: int, window: int | None = None,
                          impl: str = "xla", use_rope: bool = True):
    """One-token decode step.  x (B,1,d); cache k/v (B,Smax,K,Dh); ``length``
    (B,) valid-slot counts.  Returns (out (B,1,d), new_cache)."""
    b = x.shape[0]
    positions = length[:, None]                                   # (B,1)
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, use_rope)
    # write the new k/v at slot ``length`` (static cache, dynamic occupancy)
    slot = length                                                  # (B,)
    onehot = jax.nn.one_hot(slot, cache["k"].shape[1], dtype=cache["k"].dtype)  # (B,Smax)
    newk = cache["k"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k[:, 0:1].astype(cache["k"].dtype)
    newv = cache["v"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v[:, 0:1].astype(cache["v"].dtype)
    kv_len = length + 1
    if impl == "pallas":
        def per_example(qi, ki, vi, li):
            return kops.decode_attention(qi, ki, vi, li)
        out = jax.vmap(per_example)(q.reshape(b, n_heads, head_dim),
                                    newk, newv, kv_len)
        out = out.reshape(b, 1, n_heads, head_dim)
    else:
        out = multihead_attention(q, newk.astype(q.dtype), newv.astype(q.dtype),
                                  causal=False, window=window, impl="xla",
                                  kv_len=kv_len)
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, 1, n_heads * head_dim),
                      params["wo"], preferred_element_type=x.dtype)
    return proj, {"k": newk, "v": newv}
