"""Recurrent/SSM blocks: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both reduce to gated first-order recurrences:
  * RG-LRU — diagonal state: h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t);
    evaluated with the ``linear_scan`` Pallas kernel (time-parallel blocked
    associative scan), a_t data-dependent through the recurrence gate.
  * RWKV6 — matrix state per head: S_t = diag(w_t) S_{t-1} + kᵀ_t v_t, with
    data-dependent decay w_t and a current-token bonus u.  The baseline path
    scans over time (compiles to a fori loop); a chunked variant
    (``rwkv6_chunked``) trades it for matmul-rich O(T/c) chunk steps — the
    long-context hillclimb in EXPERIMENTS.md §Perf compares the two.

Decode paths carry the state explicitly — these architectures are why the
``long_500k`` shape is runnable at all (state size is O(d²/head), not O(S)).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from ..kernels import ops as kops

_C_RGLRU = 8.0


# =============================================================================
# RG-LRU (RecurrentGemma)
# =============================================================================
def rglru_block_init(key, d_model: int, d_rnn: int, conv_width: int = 4,
                     dtype=layers.DEFAULT_PARAM_DTYPE) -> dict:
    ks = jax.random.split(key, 7)
    return {
        "w_x": layers.dense_init(ks[0], d_model, d_rnn, dtype),
        "w_gate_branch": layers.dense_init(ks[1], d_model, d_rnn, dtype),
        "conv": (jax.random.normal(ks[2], (conv_width, d_rnn), jnp.float32) * 0.02).astype(dtype),
        "w_input_gate": layers.dense_init(ks[3], d_rnn, d_rnn, dtype),
        "w_rec_gate": layers.dense_init(ks[4], d_rnn, d_rnn, dtype),
        "lam": jnp.asarray(np.linspace(2.0, 5.0, d_rnn), jnp.float32),  # Λ init
        "w_out": layers.dense_init(ks[5], d_rnn, d_model, dtype),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,C), w (W,C) depthwise causal conv."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _rglru_coeffs(params, u):
    """u (B,S,dr) → recurrence coefficients a, b (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, params["w_rec_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, params["w_input_gate"]).astype(jnp.float32))
    log_a = -_C_RGLRU * r * jax.nn.softplus(params["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * uf)
    return a, b


def rglru_block(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Griffin recurrent block: conv → RG-LRU, gated by a GeLU branch."""
    u = jnp.einsum("bsd,de->bse", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate_branch"])
                       .astype(jnp.float32))
    u = _causal_conv1d(u, params["conv"])
    a, b = _rglru_coeffs(params, u)
    h = jax.vmap(kops.linear_scan)(a, b)                       # (B,S,dr) f32
    out = (h * gate).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", out, params["w_out"])


def rglru_decode_init(batch: int, d_rnn: int, conv_width: int = 4) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv_buf": jnp.zeros((batch, conv_width - 1, d_rnn), jnp.bfloat16),
    }


def rglru_decode(params: dict, x: jnp.ndarray, state: dict):
    """x (B,1,d) single step; returns (out (B,1,d), new_state)."""
    u = jnp.einsum("bsd,de->bse", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate_branch"])
                       .astype(jnp.float32))
    buf = jnp.concatenate([state["conv_buf"].astype(u.dtype), u], axis=1)  # (B,W,dr)
    w = params["conv"]
    u_conv = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32),
                        w.astype(jnp.float32))[:, None].astype(u.dtype)
    a, b = _rglru_coeffs(params, u_conv)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = (h[:, None] * gate).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, params["w_out"])
    return out, {"h": h, "conv_buf": buf[:, 1:].astype(jnp.bfloat16)}


# =============================================================================
# RWKV6 (Finch)
# =============================================================================
def rwkv6_block_init(key, d_model: int, head_dim: int = 64,
                     dtype=layers.DEFAULT_PARAM_DTYPE) -> dict:
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 10)
    lowrank = 32
    return {
        "mu": (jax.random.normal(ks[0], (5, d_model), jnp.float32) * 0.02).astype(jnp.float32),
        "w_r": layers.dense_init(ks[1], d_model, d_model, dtype),
        "w_k": layers.dense_init(ks[2], d_model, d_model, dtype),
        "w_v": layers.dense_init(ks[3], d_model, d_model, dtype),
        "w_g": layers.dense_init(ks[4], d_model, d_model, dtype),
        "w_o": layers.dense_init(ks[5], d_model, d_model, dtype),
        # data-dependent decay: low-rank ddlerp (Finch's token-shift decay)
        "decay_a": layers.dense_init(ks[6], d_model, lowrank, jnp.float32),
        "decay_b": layers.dense_init(ks[7], lowrank, d_model, jnp.float32),
        "decay_base": jnp.asarray(np.linspace(-6.0, -0.5, d_model), jnp.float32),
        "bonus": (jax.random.normal(ks[8], (n_heads, head_dim), jnp.float32) * 0.02),
        "ln_out": layers.layernorm_init(d_model),
    }


def _rwkv6_inputs(params, x, x_prev):
    """Token-shift mixes current with previous token (Finch ddlerp, simplified
    to static per-projection mix weights mu[0..4] for r,k,v,g,w)."""
    mix = lambda i: x * (1 - params["mu"][i]) + x_prev * params["mu"][i]
    xr, xk, xv, xg, xw = (mix(i).astype(x.dtype) for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, params["w_r"])
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"])
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"]).astype(jnp.float32))
    dd = jnp.einsum("bsd,dl->bsl", xw.astype(jnp.float32), params["decay_a"])
    dd = jnp.einsum("bsl,ld->bsd", jnp.tanh(dd), params["decay_b"])
    w = jnp.exp(-jnp.exp(params["decay_base"] + dd))           # (B,S,d) ∈ (0,1)
    return r, k, v, g, w


def rwkv6_block(params: dict, x: jnp.ndarray, *, head_dim: int = 64) -> jnp.ndarray:
    """Time-mixing with matrix state, scan-over-time baseline."""
    b, s, d = x.shape
    h = d // head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv6_inputs(params, x, x_prev)
    rh = r.reshape(b, s, h, head_dim).astype(jnp.float32)
    kh = k.reshape(b, s, h, head_dim).astype(jnp.float32)
    vh = v.reshape(b, s, h, head_dim).astype(jnp.float32)
    wh = w.reshape(b, s, h, head_dim)
    u = params["bonus"]                                        # (H,Dk)

    def step(S, inp):
        rt, kt, vt, wt = inp                                   # (B,H,Dk)... vt (B,H,Dv)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    S0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    xs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
          vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    _, outs = jax.lax.scan(step, S0, xs)
    out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
    out = layers.layernorm(params["ln_out"], out) * g
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["w_o"])


def rwkv6_chunked(params: dict, x: jnp.ndarray, *, head_dim: int = 64,
                  chunk: int = 64) -> jnp.ndarray:
    """Chunked linear-attention formulation: O(T/c) scan steps of matmuls
    instead of O(T) elementwise steps — the §Perf optimized path."""
    b, s, d = x.shape
    h = d // head_dim
    pad = (-s) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    x_prev = jnp.pad(xp, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv6_inputs(params, xp, x_prev)
    nch = sp // chunk
    rs = r.reshape(b, nch, chunk, h, head_dim).astype(jnp.float32)
    ks_ = k.reshape(b, nch, chunk, h, head_dim).astype(jnp.float32)
    vs = v.reshape(b, nch, chunk, h, head_dim).astype(jnp.float32)
    ws = w.reshape(b, nch, chunk, h, head_dim).astype(jnp.float32)
    u = params["bonus"]

    logw = jnp.log(jnp.maximum(ws, 1e-12))
    cum = jnp.cumsum(logw, axis=2)                             # within-chunk cumulative
    total = cum[:, :, -1]                                      # (B,N,H,Dk)

    def chunk_step(S, inp):
        rc, kc, vc, lwc, cumc, totc = inp
        # inter-chunk: r_t decayed against state entering the chunk
        r_dec = rc * jnp.exp(cumc - lwc)                       # r_t ⊙ Πw_{≤t-1}
        inter = jnp.einsum("bthk,bhkv->bthv", r_dec, S)
        # intra-chunk: pairs j < t with decay Πw_{j+1..t-1}
        k_dec = kc * jnp.exp(-cumc)                            # k_j / Πw_{≤j}
        att = jnp.einsum("bthk,bjhk->bhtj", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((rc.shape[1], rc.shape[1]), jnp.float32), -1)
        att = att * tri[None, None]
        intra = jnp.einsum("bhtj,bjhv->bthv", att, vc)
        # current-token bonus
        bonus = jnp.einsum("bthk,hk,bthk->bth", rc, u, kc)
        cur = bonus[..., None] * vc
        out = inter + intra + cur
        # state update: S' = diag(Πw_chunk) S + Σ_j (Πw_{j+1..end}) kᵀv
        k_tail = kc * jnp.exp(totc[:, None] - cumc)            # Πw_{j+1..end}
        S = jnp.exp(totc)[..., None] * S + jnp.einsum("bjhk,bjhv->bhkv", k_tail, vc)
        return S, out

    S0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    seq = (rs.transpose(1, 0, 2, 3, 4), ks_.transpose(1, 0, 2, 3, 4),
           vs.transpose(1, 0, 2, 3, 4), logw.transpose(1, 0, 2, 3, 4),
           cum.transpose(1, 0, 2, 3, 4),
           total.transpose(1, 0, 2, 3))
    _, outs = jax.lax.scan(chunk_step, S0, seq)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sp, d)[:, :s]
    out = layers.layernorm(params["ln_out"], out) * g[:, :s]
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["w_o"])


def rwkv_cmix_init(key, d_model: int, d_ff: int,
                   dtype=layers.DEFAULT_PARAM_DTYPE) -> dict:
    """RWKV6 channel-mixing (replaces the MLP in rwkv blocks)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": (jax.random.normal(k1, (2, d_model), jnp.float32) * 0.02),
        "w_k": layers.dense_init(k2, d_model, d_ff, dtype),
        "w_v": layers.dense_init(k3, d_ff, d_model, dtype),
        "w_r": layers.dense_init(jax.random.fold_in(k1, 7), d_model, d_model, dtype),
    }


def rwkv_cmix(params: dict, x: jnp.ndarray,
              x_prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """out = σ(W_r x_r) ⊙ W_v(relu(W_k x_k)²), with token-shift mixes."""
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = (x * (1 - params["mu"][0]) + x_prev * params["mu"][0]).astype(x.dtype)
    xr = (x * (1 - params["mu"][1]) + x_prev * params["mu"][1]).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, params["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_r"]).astype(jnp.float32))
    return (r * jnp.einsum("bsf,fd->bsd", k, params["w_v"]).astype(jnp.float32)).astype(x.dtype)


def rwkv6_decode_init(batch: int, d_model: int, head_dim: int = 64) -> dict:
    h = d_model // head_dim
    return {
        "S": jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        "x_prev": jnp.zeros((batch, d_model), jnp.bfloat16),
    }


def rwkv6_decode(params: dict, x: jnp.ndarray, state: dict, *, head_dim: int = 64):
    """x (B,1,d) one step."""
    b, _, d = x.shape
    h = d // head_dim
    r, k, v, g, w = _rwkv6_inputs(params, x, state["x_prev"][:, None].astype(x.dtype))
    rt = r.reshape(b, h, head_dim).astype(jnp.float32)
    kt = k.reshape(b, h, head_dim).astype(jnp.float32)
    vt = v.reshape(b, h, head_dim).astype(jnp.float32)
    wt = w.reshape(b, h, head_dim)
    u = params["bonus"]
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, state["S"] + u[None, :, :, None] * kv)
    S = wt[..., None] * state["S"] + kv
    out = out.reshape(b, 1, d)
    out = layers.layernorm(params["ln_out"], out) * g
    out = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["w_o"])
    return out, {"S": S, "x_prev": x[:, 0].astype(jnp.bfloat16)}
