"""Shared neural layers (pure-JAX, functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; init fns take (key, cfg-ish args)
  * compute dtype is bf16 by default with f32 norms/softmax/logits
  * weight matrices are stored (in_dim, out_dim) so TP sharding specs read
    naturally as P(None, "model") column-parallel / P("model", None)
    row-parallel.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_PARAM_DTYPE = jnp.bfloat16
DEFAULT_COMPUTE_DTYPE = jnp.bfloat16


# -----------------------------------------------------------------------------
# init helpers
# -----------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype=DEFAULT_PARAM_DTYPE,
               scale: float | None = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DEFAULT_PARAM_DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# -----------------------------------------------------------------------------
# norms
# -----------------------------------------------------------------------------
def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# rotary position embeddings
# -----------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                          # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# MLPs
# -----------------------------------------------------------------------------
def swiglu_init(key, d_model: int, d_ff: int, dtype=DEFAULT_PARAM_DTYPE) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    # residual-dtype dot output: keeps the row-parallel TP psum in bf16
    # (f32 dot accumulation would make GSPMD all-reduce f32 partials — 2×
    # the wire bytes; see EXPERIMENTS.md §Perf llama-90b iteration 4)
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=x.dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=DEFAULT_PARAM_DTYPE) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["w_up"]) + params["b_up"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=x.dtype) + params["b_down"]


# -----------------------------------------------------------------------------
# embedding / unembedding
# -----------------------------------------------------------------------------
def embed(params: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params, tokens, axis=0)


def unembed(params: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(..., d) @ (V, d)^T → (..., V) logits in f32."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params.astype(jnp.float32))


# -----------------------------------------------------------------------------
# losses
# -----------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None, z_loss: float = 1e-4):
    """Next-token cross entropy with optional z-loss; logits (..., V) f32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * logz ** 2
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = np.prod(labels.shape)
    return nll.sum() / denom
