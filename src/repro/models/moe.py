"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch
(GShard/Switch style) and expert parallelism over the "model" mesh axis.

Dispatch avoids the O(T·E·C) combine tensor: slot positions come from a
cumulative-sum over the (T·k, E) assignment one-hot, tokens are scattered
into the (E, C, d) expert buffers, and the combine is a gather weighted by
the router gates.  With experts sharded P("model", ...) and tokens sharded
P("data", ...), GSPMD lowers the scatter/gather into the MoE all-to-all —
the collective the roofline analysis watches for MoE cells.

Aux losses: load-balancing (Switch) + router z-loss.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             dtype=layers.DEFAULT_PARAM_DTYPE) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    return {
        "router": layers.dense_init(kr, d_model, n_experts, jnp.float32),
        "w_gate": (jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, d_ff, d_model), jnp.float32) * scale_out).astype(dtype),
    }


def _gcd_groups(t: int, want: int) -> int:
    import math
    return max(1, math.gcd(t, want))


def moe_apply(params: dict, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25,
              n_groups: int = 16) -> tuple[jnp.ndarray, dict]:
    """x (B, S, d) → (B, S, d), aux {load_balance_loss, router_z_loss}.

    Dispatch is **group-local** (GShard "groups"): tokens are split into G
    independent routing groups, each with its own capacity and slot space, so
    the position-cumsum and the scatter/gather are local to a group.  With G
    a multiple of the DP shard count, GSPMD keeps all dispatch bookkeeping
    shard-local and the only cross-chip movement is the (G,E,C,d)↔expert
    all-to-all — without groups the global-T cumsum replicates a (T·k, E)
    tensor on every chip (hundreds of GB at 1M tokens)."""
    b, s, d = x.shape
    t = b * s
    g = _gcd_groups(t, n_groups)
    tg = t // g
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (G,Tg,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)           # (G,Tg,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(np.ceil(tg * top_k / n_experts * capacity_factor))
    capacity = max(capacity, top_k)

    # slot positions within each group: cumsum over the (k·Tg, E) one-hot,
    # choice-major so primary routes win capacity.
    flat_idx = expert_idx.transpose(0, 2, 1).reshape(g, top_k * tg)  # (G,kTg)
    onehot = jax.nn.one_hot(flat_idx, n_experts, dtype=jnp.int32)    # (G,kTg,E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    slot_flat = jnp.take_along_axis(pos, flat_idx[..., None], axis=2)[..., 0]
    slot = slot_flat.reshape(g, top_k, tg).transpose(0, 2, 1)        # (G,Tg,k)
    keep = slot < capacity

    e_flat = expert_idx.reshape(g, tg * top_k)
    s_flat = slot.reshape(g, tg * top_k)
    keep_flat = keep.reshape(g, tg * top_k)
    e_safe = jnp.where(keep_flat, e_flat, 0)
    s_safe = jnp.where(keep_flat, s_flat, 0)
    src = jnp.repeat(xt, top_k, axis=1)                              # (G,Tg·k,d)
    src = jnp.where(keep_flat[..., None], src, 0)

    def dispatch(buf_g, e_g, s_g, src_g):
        return buf_g.at[e_g, s_g].add(src_g)

    buffers = jnp.zeros((g, n_experts, capacity, d), xt.dtype)
    buffers = jax.vmap(dispatch)(buffers, e_safe, s_safe, src)       # (G,E,C,d)

    # expert computation (SwiGLU) — E shards over "model" (EP); G over DP.
    gg = jnp.einsum("gecd,edf->gecf", buffers, params["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", buffers, params["w_up"])
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(buffers.dtype) * uu
    out_buf = jnp.einsum("gecf,efd->gecd", hh, params["w_down"])     # (G,E,C,d)

    def combine(out_g, e_g, s_g):
        return out_g[e_g, s_g]

    gathered = jax.vmap(combine)(out_buf, e_safe, s_safe)            # (G,Tg·k,d)
    gathered = jnp.where(keep_flat[..., None], gathered, 0)
    w = gate_vals.reshape(g, tg * top_k)                             # token-major
    weighted = gathered * w[..., None].astype(gathered.dtype)
    out = weighted.reshape(g, tg, top_k, d).sum(axis=2)

    # aux losses
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(expert_idx[..., 0], n_experts).mean(axis=(0, 1))
    load_balance = n_experts * jnp.sum(me * ce)
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance_loss": load_balance, "router_z_loss": router_z}
    return out.reshape(b, s, d).astype(x.dtype), aux
