"""Activation-sharding context: pins batch sharding through the network.

With FSDP-style weight sharding (weights sharded over the DP axis), GSPMD
sometimes prefers resharding *activations* (replicating the batch!) over
all-gathering weights — catastrophic for memory.  Pinning the hidden-state
sharding at block boundaries forces the intended plan: batch stays on the DP
axes, weights all-gather just-in-time (ZeRO-3 semantics).

The context is consulted at **trace time**: the dry-run / trainer wraps
``jit(...).lower(...)`` in ``activation_sharding(mesh, dp_axes)``; without an
active context every constraint is the identity, so tests and single-device
runs are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_act_sharding",
                                                      default=None)


@contextlib.contextmanager
def activation_sharding(mesh, dp_axes: tuple[str, ...]):
    token = _CTX.set((mesh, tuple(dp_axes)))
    try:
        yield
    finally:
        _CTX.reset(token)


def _dp_size(mesh, dp) -> int:
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def constrain_hidden(x):
    """x (B, S, d) or (B, 1, d): pin B to the DP axes when divisible."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, dp = ctx
    b = x.shape[0]
    dp_entry = dp if len(dp) > 1 else dp[0]
    if b % _dp_size(mesh, dp) != 0:
        return x
    spec = P(dp_entry, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
