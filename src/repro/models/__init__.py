"""Model zoo: the 10 assigned architectures as composable pure-JAX modules."""
from .model import Model, build_model, cache_specs, input_specs, params_specs  # noqa: F401
