"""Transformer stack assembly: scan-over-layers, heterogeneous patterns,
train / prefill / decode entry points.

A stack is ``repeats`` scanned superblocks (pattern positions unrolled inside
the scan body, params stacked over the repeat dim) plus an unrolled tail for
``n_layers % len(pattern)``.  Scan keeps the lowered HLO O(pattern) instead of
O(n_layers) — required for 94–100-layer dry-run compiles — and composes with
``jax.checkpoint`` for per-superblock remat.

Decode threads a per-layer cache pytree (stacked the same way) through the
same scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, layers, moe, recurrent
from .sharding_ctx import constrain_hidden
from ..configs.base import ArchConfig


# =============================================================================
# parameter init
# =============================================================================
def _norm_init(cfg: ArchConfig):
    return layers.rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm" \
        else layers.layernorm_init(cfg.d_model)


def _norm_apply(cfg: ArchConfig, p, x):
    return layers.rmsnorm(p, x) if cfg.norm == "rmsnorm" else layers.layernorm(p, x)


def _block_init(key, kind: str, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": _norm_init(cfg), "ln2": _norm_init(cfg)}
    if kind in ("attn", "local", "global"):
        p["attn"] = attention.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                        cfg.head_dim_, cfg.qkv_bias)
    elif kind == "cross":
        p["cross"] = attention.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                         cfg.head_dim_, cfg.qkv_bias)
    elif kind == "dec":
        p["attn"] = attention.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                        cfg.head_dim_, cfg.qkv_bias)
        p["lnx"] = _norm_init(cfg)
        p["cross"] = attention.attn_init(ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                         cfg.head_dim_, cfg.qkv_bias)
    elif kind == "rglru":
        p["rglru"] = recurrent.rglru_block_init(ks[0], cfg.d_model, cfg.rnn_width_)
    elif kind == "rwkv":
        p["rwkv"] = recurrent.rwkv6_block_init(ks[0], cfg.d_model, cfg.rwkv_head_dim)
    else:
        raise ValueError(kind)

    if cfg.mlp == "moe":
        p["mlp"] = moe.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts)
    elif cfg.mlp == "gelu":
        p["mlp"] = layers.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    elif cfg.mlp == "rwkv_cmix":
        p["mlp"] = recurrent.rwkv_cmix_init(ks[1], cfg.d_model, cfg.d_ff)
    else:
        p["mlp"] = layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    keys = jax.random.split(key, 8)
    repeats, tail = cfg.repeats_and_tail()
    params: dict[str, Any] = {
        "embed": layers.embed_init(keys[0], cfg.vocab, cfg.d_model),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embed_init(keys[1], cfg.vocab, cfg.d_model)

    # scanned superblocks: one stacked param tree per pattern position
    def stacked(kind: str, base_key, n: int):
        inits = [_block_init(jax.random.fold_in(base_key, i), kind, cfg)
                 for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *inits) if n > 1 else (
            jax.tree.map(lambda x: x[None], inits[0]) if n == 1 else None)

    if repeats > 0:
        params["blocks"] = [stacked(kind, jax.random.fold_in(keys[2], pi), repeats)
                            for pi, kind in enumerate(cfg.pattern)]
    else:
        params["blocks"] = []
    params["tail"] = [_block_init(jax.random.fold_in(keys[3], i), cfg.pattern[i], cfg)
                      for i in range(tail)]

    if cfg.encoder_layers:
        enc_cfg = cfg
        params["encoder"] = {
            "enc_layers": [_block_init(jax.random.fold_in(keys[4], i), "attn", enc_cfg)
                           for i in range(cfg.encoder_layers)],
            "final_norm": _norm_init(cfg),
        }
    return params


# =============================================================================
# forward blocks
# =============================================================================
def _pick_impl(cfg: ArchConfig, seq_len: int) -> str:
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    return "chunked" if seq_len > 2048 else "xla"


def _block_apply(kind: str, p: dict, x: jnp.ndarray, cfg: ArchConfig,
                 memory: jnp.ndarray | None, impl: str,
                 causal: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, moe_aux_loss)."""
    hd = cfg.head_dim_
    h = _norm_apply(cfg, p["ln1"], x)
    if kind in ("attn", "global"):
        y = attention.self_attention(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                     head_dim=hd, causal=causal, impl=impl,
                                     use_rope=cfg.use_rope)
    elif kind == "local":
        y = attention.self_attention(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                     head_dim=hd, causal=causal, window=cfg.window,
                                     impl=impl, use_rope=cfg.use_rope)
    elif kind == "cross":
        y = attention.cross_attention(p["cross"], h, memory, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv, head_dim=hd, impl=impl)
    elif kind == "dec":
        y = attention.self_attention(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                     head_dim=hd, causal=True, impl=impl,
                                     use_rope=cfg.use_rope)
        x = x + y
        hx = _norm_apply(cfg, p["lnx"], x)
        y = attention.cross_attention(p["cross"], hx, memory, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv, head_dim=hd, impl=impl)
    elif kind == "rglru":
        y = recurrent.rglru_block(p["rglru"], h)
    elif kind == "rwkv":
        y = (recurrent.rwkv6_chunked(p["rwkv"], h, head_dim=cfg.rwkv_head_dim)
             if cfg.rwkv_chunked else
             recurrent.rwkv6_block(p["rwkv"], h, head_dim=cfg.rwkv_head_dim))
    else:
        raise ValueError(kind)
    x = x + y

    h2 = _norm_apply(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.mlp == "moe":
        m, auxd = moe.moe_apply(p["mlp"], h2, n_experts=cfg.n_experts,
                                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                                n_groups=cfg.moe_groups)
        aux = auxd["load_balance_loss"] * 0.01 + auxd["router_z_loss"] * 1e-3
    elif cfg.mlp == "gelu":
        m = layers.gelu_mlp(p["mlp"], h2)
    elif cfg.mlp == "rwkv_cmix":
        m = recurrent.rwkv_cmix(p["mlp"], h2)
    else:
        m = layers.swiglu(p["mlp"], h2)
    return x + m, aux


# =============================================================================
# train / prefill forward
# =============================================================================
def forward(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
            memory: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) → (logits (B,S,V) f32, moe_aux scalar)."""
    b, s = tokens.shape
    impl = _pick_impl(cfg, s)
    x = layers.embed(params["embed"], tokens) * np.sqrt(cfg.d_model)
    x = x.astype(jnp.bfloat16)
    if not cfg.use_rope:
        x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)

    if cfg.encoder_layers and memory is not None:
        memory = encode(params["encoder"], memory, cfg)

    x = constrain_hidden(x)

    def superblock(x, block_params):
        aux = jnp.zeros((), jnp.float32)
        for pi, kind in enumerate(cfg.pattern):
            x, a = _block_apply(kind, block_params[pi], x, cfg, memory, impl)
            x = constrain_hidden(x)
            aux = aux + a
        return x, aux

    sb = superblock
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        sb = jax.checkpoint(superblock, policy=policy)

    repeats, _ = cfg.repeats_and_tail()
    aux_total = jnp.zeros((), jnp.float32)
    if repeats > 0 and cfg.scan_layers:
        def scan_body(carry, layer_params):
            x, aux = carry
            x, a = sb(x, layer_params)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total), params["blocks"])
    elif repeats > 0:
        for r in range(repeats):   # unrolled (probe compiles / tiny models)
            x, a = sb(x, _index_layer(params["blocks"], r))
            aux_total = aux_total + a
    for i, p in enumerate(params["tail"]):
        x, a = _block_apply(cfg.pattern[i], p, x, cfg, memory, impl)
        aux_total = aux_total + a

    x = _norm_apply(cfg, params["final_norm"], x)
    unemb = params.get("unembed", params["embed"])
    logits = layers.unembed(unemb, x)
    return logits, aux_total


def encode(enc_params: dict, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Whisper-style encoder over stubbed frame embeddings (B,F,d)."""
    x = frames.astype(jnp.bfloat16) + _sinusoid(frames.shape[1], cfg.d_model).astype(jnp.bfloat16)
    impl = _pick_impl(cfg, frames.shape[1])
    for p in enc_params["enc_layers"]:
        x, _ = _block_apply("attn", p, x, cfg, None, impl, causal=False)
    return _norm_apply(cfg, enc_params["final_norm"], x)


def _index_layer(tree, r: int):
    return jax.tree.map(lambda x: x[r], tree)


@functools.lru_cache(maxsize=8)
def _sinusoid_np(s: int, d: int):
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _sinusoid(s: int, d: int) -> jnp.ndarray:
    return jnp.asarray(_sinusoid_np(s, d))


# =============================================================================
# serving: cache structure + prefill + decode
# =============================================================================
def _layer_cache_init(kind: str, cfg: ArchConfig, batch: int, s_max: int) -> dict:
    hd = cfg.head_dim_
    if kind in ("attn", "global", "dec"):
        return attention.cache_init(batch, s_max, cfg.n_kv, hd)
    if kind == "local":
        return attention.cache_init(batch, min(s_max, (cfg.window or s_max)), cfg.n_kv, hd)
    if kind == "cross":
        return {}
    if kind == "rglru":
        return recurrent.rglru_decode_init(batch, cfg.rnn_width_)
    if kind == "rwkv":
        c = recurrent.rwkv6_decode_init(batch, cfg.d_model, cfg.rwkv_head_dim)
        c["cmix_prev"] = jnp.zeros((batch, cfg.d_model), jnp.bfloat16)
        return c
    raise ValueError(kind)


def cache_init(cfg: ArchConfig, batch: int, s_max: int) -> dict:
    repeats, tail = cfg.repeats_and_tail()

    def stacked(kind: str):
        one = _layer_cache_init(kind, cfg, batch, s_max)
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (repeats,) + x.shape), one)

    return {
        "blocks": [stacked(kind) for kind in cfg.pattern] if repeats else [],
        "tail": [_layer_cache_init(cfg.pattern[i], cfg, batch, s_max)
                 for i in range(tail)],
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _decode_block(kind: str, p: dict, x, cache: dict, length, cfg: ArchConfig,
                  memory) -> tuple[jnp.ndarray, dict]:
    hd = cfg.head_dim_
    h = _norm_apply(cfg, p["ln1"], x)
    new_cache = cache
    if kind in ("attn", "global"):
        y, new_cache = attention.decode_self_attention(
            p["attn"], h, cache, length, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=hd, use_rope=cfg.use_rope)
    elif kind == "local":
        y, new_cache = _decode_local(p["attn"], h, cache, length, cfg)
    elif kind == "cross":
        y = attention.cross_attention(p["cross"], h, memory, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv, head_dim=hd)
    elif kind == "dec":
        y, new_cache = attention.decode_self_attention(
            p["attn"], h, cache, length, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=hd, use_rope=cfg.use_rope)
        x = x + y
        hx = _norm_apply(cfg, p["lnx"], x)
        y = attention.cross_attention(p["cross"], hx, memory, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv, head_dim=hd)
    elif kind == "rglru":
        y, new_cache = recurrent.rglru_decode(p["rglru"], h, cache)
    elif kind == "rwkv":
        y, new_cache = recurrent.rwkv6_decode(p["rwkv"], h, cache,
                                              head_dim=cfg.rwkv_head_dim)
    else:
        raise ValueError(kind)
    x = x + y
    h2 = _norm_apply(cfg, p["ln2"], x)
    if cfg.mlp == "moe":
        m, _ = moe.moe_apply(p["mlp"], h2, n_experts=cfg.n_experts, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             n_groups=cfg.moe_groups)
    elif cfg.mlp == "gelu":
        m = layers.gelu_mlp(p["mlp"], h2)
    elif cfg.mlp == "rwkv_cmix":
        xp = cache.get("cmix_prev") if kind == "rwkv" else None
        m = recurrent.rwkv_cmix(p["mlp"], h2,
                                x_prev=None if xp is None else xp[:, None].astype(h2.dtype))
    else:
        m = layers.swiglu(p["mlp"], h2)
    if kind == "rwkv":
        new_cache = dict(new_cache)
        new_cache["cmix_prev"] = h2[:, 0].astype(jnp.bfloat16)
    return x + m, new_cache


def _decode_local(p, h, cache, length, cfg: ArchConfig):
    """Local-window decode: ring-buffer cache of ``window`` slots."""
    w = cache["k"].shape[1]
    b = h.shape[0]
    positions = length[:, None]
    q, k, v = attention._project_qkv(p, h, cfg.n_heads, cfg.n_kv, cfg.head_dim_,
                                     positions, cfg.use_rope)
    slot = length % w
    onehot = jax.nn.one_hot(slot, w, dtype=cache["k"].dtype)
    newk = cache["k"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k[:, 0:1].astype(cache["k"].dtype)
    newv = cache["v"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v[:, 0:1].astype(cache["v"].dtype)
    kv_len = jnp.minimum(length + 1, w)
    out = attention.multihead_attention(q, newk.astype(q.dtype), newv.astype(q.dtype),
                                        causal=False, impl="xla", kv_len=kv_len)
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, 1, cfg.n_heads * cfg.head_dim_),
                      p["wo"])
    return proj, {"k": newk, "v": newv}


def decode_step(params: dict, token: jnp.ndarray, cache: dict, cfg: ArchConfig,
                memory: jnp.ndarray | None = None) -> tuple[jnp.ndarray, dict]:
    """token (B,) one decode step → (logits (B,V) f32, new cache).

    ``memory`` must be *already encoded* (the engine runs the encoder once at
    prefill; decode never re-encodes)."""
    b = token.shape[0]
    length = cache["length"]
    x = layers.embed(params["embed"], token[:, None]) * np.sqrt(cfg.d_model)
    x = x.astype(jnp.bfloat16)
    if not cfg.use_rope:
        # sinusoidal position at the current slot
        d = cfg.d_model
        half = d // 2
        i = jnp.arange(half, dtype=jnp.float32)
        ang = length[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
        pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pos[:, None].astype(x.dtype)

    repeats, tail = cfg.repeats_and_tail()
    x = constrain_hidden(x)
    new_blocks = []
    if repeats > 0 and cfg.scan_layers:
        def scan_body(x, per_repeat):
            block_params, block_caches = per_repeat
            new_caches = []
            for pi, kind in enumerate(cfg.pattern):
                x, nc = _decode_block(kind, block_params[pi], x, block_caches[pi],
                                      length, cfg, memory)
                x = constrain_hidden(x)
                new_caches.append(nc)
            return x, new_caches
        x, new_blocks = jax.lax.scan(scan_body, x,
                                     (params["blocks"], cache["blocks"]))
    elif repeats > 0:
        per_repeat_caches = []
        for r in range(repeats):
            caches_r = []
            for pi, kind in enumerate(cfg.pattern):
                x, nc = _decode_block(kind, _index_layer(params["blocks"][pi], r),
                                      x, _index_layer(cache["blocks"][pi], r),
                                      length, cfg, memory)
                caches_r.append(nc)
            per_repeat_caches.append(caches_r)
        # restack: list over repeats of per-position caches → stacked trees
        new_blocks = [
            jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[per_repeat_caches[r][pi] for r in range(repeats)])
            for pi in range(len(cfg.pattern))
        ]
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, nc = _decode_block(cfg.pattern[i], p, x, cache["tail"][i], length, cfg, memory)
        new_tail.append(nc)

    x = _norm_apply(cfg, params["final_norm"], x)
    unemb = params.get("unembed", params["embed"])
    logits = layers.unembed(unemb, x)[:, 0]
    new_cache = {"blocks": new_blocks, "tail": new_tail, "length": length + 1}
    return logits, new_cache


def prefill(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
            memory: jnp.ndarray | None = None):
    """Prefill = forward pass producing last-position logits.  (The serving
    engine then fills the cache via teacher-forced decode or chunked prefill;
    for the dry-run cost model, prefill is the forward itself.)"""
    logits, _ = forward(params, tokens, cfg, memory)
    return logits[:, -1]
