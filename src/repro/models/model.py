"""Unified model interface: ``build_model(cfg)`` → init / loss / serve fns.

The returned ``Model`` is what the trainer, the serving engine, and the
dry-run all consume.  ``input_specs`` produces ShapeDtypeStruct stand-ins for
every (shape-kind) input so the dry-run lowers without allocating (modality
frontends are stubs: precomputed frame/patch embeddings, per the assignment).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, transformer
from ..configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable                  # (key) -> params
    loss_fn: Callable               # (params, batch) -> (loss, metrics)
    forward: Callable               # (params, tokens, memory?) -> logits
    prefill: Callable               # (params, tokens, memory?) -> last logits
    decode_step: Callable           # (params, token, cache, memory?) -> (logits, cache)
    cache_init: Callable            # (batch, s_max) -> cache

    def param_count(self) -> tuple[int, int]:
        return self.cfg.param_count()


def _needs_memory(cfg: ArchConfig) -> bool:
    return cfg.cross_memory_len > 0


def build_model(cfg: ArchConfig) -> Model:
    def init(key):
        return transformer.init_params(key, cfg)

    def forward(params, tokens, memory=None):
        logits, _ = transformer.forward(params, tokens, cfg, memory)
        return logits

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        mask = batch.get("mask")
        memory = batch.get("memory")
        logits, aux = transformer.forward(params, tokens, cfg, memory)
        xent = layers.softmax_xent(logits, labels, mask)
        loss = xent + aux
        return loss, {"xent": xent, "moe_aux": aux}

    def prefill(params, tokens, memory=None):
        return transformer.prefill(params, tokens, cfg, memory)

    def decode_step(params, token, cache, memory=None):
        return transformer.decode_step(params, token, cache, cfg, memory)

    def cache_init(batch, s_max):
        return transformer.cache_init(cfg, batch, s_max)

    return Model(cfg, init, loss_fn, forward, prefill, decode_step, cache_init)


# =============================================================================
# ShapeDtypeStruct input specs for the dry-run (no allocation)
# =============================================================================
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), tok)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), tok)
        specs["mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), tok)
    else:  # decode: one new token against an S-long cache
        specs["token"] = jax.ShapeDtypeStruct((b,), tok)
    if _needs_memory(cfg):
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.cross_memory_len, cfg.d_model), jnp.bfloat16)
    return specs


def cache_specs(cfg: ArchConfig, batch: int, s_max: int) -> Any:
    """ShapeDtypeStruct pytree of the decode cache (eval_shape — no alloc)."""
    return jax.eval_shape(lambda: transformer.cache_init(cfg, batch, s_max))


def params_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
