"""Data pipeline: the dataframe system feeding training (paper → practice)."""
from .pipeline import DataPipeline, PipelineConfig  # noqa: F401
from .synthetic import numeric_matrix_frame, synthetic_corpus, taxi_like_frame  # noqa: F401
from .tokenizer import HashTokenizer  # noqa: F401
