"""Hashing tokenizer (offline-friendly; no external vocab files).

Whitespace/punct word split → stable FNV-1a hash → [n_special, vocab).  Not a
linguistic tokenizer — it's the data-pipeline stand-in so the end-to-end
training examples run hermetically."""
from __future__ import annotations

import re

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3
_WORD = re.compile(r"\w+|[^\w\s]")


def _fnv1a(s: str) -> int:
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h ^= ch
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > N_SPECIAL + 1
        self.vocab_size = vocab_size

    def encode(self, text: str, add_bos: bool = True) -> list[int]:
        ids = [BOS] if add_bos else []
        for w in _WORD.findall(text.lower()):
            ids.append(N_SPECIAL + _fnv1a(w) % (self.vocab_size - N_SPECIAL))
        return ids

    def encode_batch(self, texts: list[str]) -> list[list[int]]:
        return [self.encode(t) for t in texts]
