"""The paper's technique as the training data pipeline (DESIGN.md §4).

Ingest → clean → select → pack → batch, with the clean/select stages
expressed as dataframe-algebra plans executed by the *opportunistic*
scheduler: while the accelerator runs step i, the session's background
threads evaluate the plan for shard i+1 — the paper's "think-time
computation" recast as compute/IO overlap.  Shard plans are pure dataframe
queries, so the reuse cache dedupes re-walks after a restart, and the
deterministic shard→batch mapping gives exactly-once resume from the
checkpoint's data cursor.

Stages per shard (dataframe algebra):
    SELECTION   word_count ≥ min_words        (quality filter)
    DROP-DUP    by text                        (dedup)
    MAP         token_count := tokenize-len    (schema-inducing metadata map)
    SORT        by token_count                 (length bucketing → less padding)
Then host-side packing into fixed (seq_len+1) examples and device batches.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterator

import jax.numpy as jnp
import numpy as np

from ..core import algebra as alg
from ..core.dtypes import Domain, parse_column
from ..core.frame import Column, Frame
from ..core.labels import labels_from_values
from ..core.session import EvalMode, Session
from .tokenizer import EOS, HashTokenizer


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int = 128
    global_batch: int = 8
    min_words: int = 4
    shard_docs: int = 512          # docs per dataframe shard
    memory_len: int = 0            # >0 ⇒ emit modality-memory stubs
    d_model: int = 0
    seed: int = 0


class DataPipeline:
    def __init__(self, texts: list[str], vocab_size: int, pc: PipelineConfig,
                 session: Session | None = None):
        self.pc = pc
        self.tok = HashTokenizer(vocab_size)
        self.session = session or Session(mode=EvalMode.OPPORTUNISTIC,
                                          default_row_parts=4)
        self.shards = [texts[i:i + pc.shard_docs]
                       for i in range(0, len(texts), pc.shard_docs)]
        self._plans: dict[int, alg.Node] = {}
        self._rng = np.random.default_rng(pc.seed)

    # ------------------------------------------------------------------
    def _shard_plan(self, i: int) -> alg.Node:
        if i in self._plans:
            return self._plans[i]
        texts = self.shards[i]
        frame = Frame.from_pydict({
            "doc_id": list(range(len(texts))),
            "text": texts,
            "word_count": [len(t.split()) for t in texts],
        })
        src = self.session.register_frame(frame, row_parts=4)
        plan = alg.Selection(src, alg.col("word_count") >= alg.lit(self.pc.min_words))
        plan = alg.DropDuplicates(plan, subset=("text",))
        tok = self.tok

        def add_token_count(cols, fr):
            texts_ = cols["text"].to_pylist()
            counts = [len(tok.encode(t or "")) for t in texts_]
            p = parse_column(counts, Domain.INT)
            out = dict(cols)
            out["token_count"] = Column(p.data, p.domain, p.mask, None)
            return Frame(list(out.values()), fr.row_labels,
                         labels_from_values(list(out.keys())))

        plan = alg.Map(plan, alg.Udf.wrap(add_token_count,
                                          name=f"tokcount_shard{i}",
                                          deps=frozenset(["text"]),
                                          elementwise=True,
                                          out_cols=("doc_id", "text", "word_count",
                                                    "token_count")))
        plan = alg.Sort(plan, ("token_count",), ascending=True)  # length bucketing
        self._plans[i] = plan
        return plan

    def _prefetch(self, i: int) -> None:
        if 0 <= i < len(self.shards):
            self.session.executor.submit(self._shard_plan(i))

    # ------------------------------------------------------------------
    def _shard_examples(self, i: int) -> np.ndarray:
        """(N, seq_len+1) int32 token matrix for shard i (deterministic)."""
        plan = self._shard_plan(i)
        self._prefetch(i + 1)  # overlap: next shard evaluates in background
        frame = self.session.collect(plan)
        texts = frame.col("text").to_pylist()
        stream: list[int] = []
        for t in texts:
            stream.extend(self.tok.encode(t or ""))
            stream.append(EOS)
        width = self.pc.seq_len + 1
        n = len(stream) // width
        if n == 0:
            return np.zeros((0, width), np.int32)
        return np.asarray(stream[: n * width], np.int32).reshape(n, width)

    def batches(self, start_batch: int = 0) -> Iterator[dict]:
        """Deterministic batch stream; ``start_batch`` resumes mid-epoch."""
        width = self.pc.seq_len + 1
        buf = np.zeros((0, width), np.int32)
        emitted = 0
        for i in range(len(self.shards)):
            buf = np.concatenate([buf, self._shard_examples(i)], axis=0)
            while buf.shape[0] >= self.pc.global_batch:
                ex, buf = buf[: self.pc.global_batch], buf[self.pc.global_batch:]
                emitted += 1
                if emitted <= start_batch:
                    continue
                yield self._to_batch(ex)

    def _to_batch(self, ex: np.ndarray) -> dict:
        batch = {
            "tokens": jnp.asarray(ex[:, :-1]),
            "labels": jnp.asarray(ex[:, 1:]),
            "mask": jnp.ones((ex.shape[0], ex.shape[1] - 1), jnp.float32),
        }
        if self.pc.memory_len:
            batch["memory"] = jnp.asarray(
                self._rng.standard_normal(
                    (ex.shape[0], self.pc.memory_len, self.pc.d_model)
                ).astype(np.float32)).astype(jnp.bfloat16)
        return batch

    def stats(self) -> dict:
        st = self.session.executor.stats
        return {
            "background_tasks": st.background_tasks,
            "cache_hits": st.cache_hits,
            "evaluated_nodes": st.evaluated_nodes,
        }
