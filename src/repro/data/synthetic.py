"""Synthetic data generators: a toy text corpus (for the end-to-end training
examples) and tabular data shaped like the paper's NYC-taxicab benchmark
(for the dataframe benchmarks — Fig. 6 uses taxi trips replicated 1–11×)."""
from __future__ import annotations

import numpy as np

from ..core.dtypes import Domain
from ..core.frame import Frame

_WORDS = (
    "the of and a to in is you that it he was for on are as with his they I "
    "at be this have from or one had by word but not what all were we when "
    "your can said there use an each which she do how their if will up other "
    "about out many then them these so some her would make like him into time "
    "has look two more write go see number no way could people my than first "
    "water been call who oil its now find long down day did get come made may"
).split()


def synthetic_corpus(n_docs: int, seed: int = 0, mean_len: int = 64) -> list[str]:
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        n = max(4, int(rng.poisson(mean_len)))
        docs.append(" ".join(rng.choice(_WORDS, size=n)))
    return docs


def taxi_like_frame(n_rows: int, seed: int = 0, n_float_cols: int = 6) -> Frame:
    """Columns mirroring the paper's benchmark data: a small-cardinality
    group key ("passenger_count"), floats with nulls, and a category."""
    rng = np.random.default_rng(seed)
    data = {
        "passenger_count": rng.integers(1, 7, n_rows).tolist(),
        "payment_type": rng.choice(["card", "cash", "dispute"], n_rows).tolist(),
    }
    for j in range(n_float_cols):
        col = rng.standard_normal(n_rows)
        nulls = rng.random(n_rows) < 0.01
        vals = [None if nulls[i] else float(col[i]) for i in range(n_rows)]
        data[f"f{j}"] = vals
    return Frame.from_pydict(data)


def numeric_matrix_frame(n_rows: int, n_cols: int, seed: int = 0) -> Frame:
    """Homogeneous float frame (matrix dataframe) — the transpose benchmark."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    mat = jnp.asarray(rng.standard_normal((n_rows, n_cols)).astype(np.float32))
    return Frame.from_matrix(mat, Domain.FLOAT)
