"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def warmup_cosine(peak_lr: float = 3e-4, warmup_steps: int = 100,
                  total_steps: int = 10_000, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        frac = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0, 1)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(np.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant(lr_value: float = 1e-3):
    def lr(step):
        return jnp.asarray(lr_value, jnp.float32)
    return lr
