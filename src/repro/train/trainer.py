"""Training loop: jitted train_step (microbatch accumulation, remat, bf16),
mesh-aware sharding, checkpoint/restart, failure recovery.

``make_train_step`` returns a single jitted function:
    state = {"params", "opt", "step"} → (state, metrics)
Gradient accumulation scans over microbatches so arbitrarily large global
batches fit; gradients stay in reduce-scatter-friendly form so XLA's
latency-hiding scheduler overlaps the psum with the backward pass.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from . import optimizer as opt_lib
from . import schedule as sched_lib
from .checkpoint import CheckpointManager
from ..models.model import Model


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 20
    total_steps: int = 1000
    microbatches: int = 1
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    log_every: int = 10
    seed: int = 0


def init_state(model: Model, key, optimizer: opt_lib.Optimizer):
    params = model.init(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(model: Model, optimizer: opt_lib.Optimizer,
                    lr_fn: Callable, microbatches: int = 1,
                    donate: bool = True) -> Callable:
    def train_step(state, batch):
        params = state["params"]

        if microbatches > 1:
            def reshape(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def acc_body(carry, mb):
                loss_sum, grad_sum = carry
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, mb)
                grad_sum = jax.tree.map(jnp.add, grad_sum, grads)
                return (loss_sum + loss, grad_sum), metrics

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), metrics = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_grads), micro)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)

        lr = lr_fn(state["step"])
        new_params, new_opt = optimizer.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                          for g in jax.tree.leaves(grads)))
        metrics = dict(metrics)
        metrics.update(loss=loss, lr=lr, grad_norm=gn)
        return new_state, metrics

    return jax.jit(train_step, donate_argnums=(0,) if donate else ())


class Trainer:
    """Loop with checkpoint/restart and step-level failure recovery."""

    def __init__(self, model: Model, tc: TrainConfig):
        self.model = model
        self.tc = tc
        self.optimizer = opt_lib.get_optimizer(model.cfg.optimizer)
        self.lr_fn = sched_lib.warmup_cosine(tc.lr, tc.warmup_steps, tc.total_steps)
        self.train_step = make_train_step(model, self.optimizer, self.lr_fn,
                                          tc.microbatches)
        self.ckpt = CheckpointManager(tc.checkpoint_dir) if tc.checkpoint_dir else None
        self.history: list[dict] = []

    def init_or_restore(self) -> tuple[Any, dict]:
        key = jax.random.PRNGKey(self.tc.seed)
        state = init_state(self.model, key, self.optimizer)
        extra = {"cursor": 0}
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state, extra = self.ckpt.restore(state)
        return state, extra

    def fit(self, batches: Iterator[dict], steps: int | None = None,
            state: Any = None, cursor: int = 0) -> Any:
        if state is None:
            state, extra = self.init_or_restore()
            cursor = extra.get("cursor", 0)
        steps = steps if steps is not None else self.tc.total_steps
        t0 = time.monotonic()
        consumed = 0
        for batch in batches:
            consumed += 1
            if consumed <= cursor:
                continue  # deterministic resume: skip already-trained batches
            state, metrics = self.train_step(state, batch)
            step = int(state["step"])
            if step % self.tc.log_every == 0 or step == 1:
                rec = {k: float(v) for k, v in metrics.items()
                       if hasattr(v, "shape") or isinstance(v, (int, float))}
                rec["step"] = step
                rec["wall_s"] = time.monotonic() - t0
                self.history.append(rec)
            if (self.ckpt is not None and self.tc.checkpoint_every
                    and step % self.tc.checkpoint_every == 0):
                self.ckpt.save(step, state, extra={"cursor": consumed})
            if step >= steps:
                break
        if self.ckpt is not None:
            self.ckpt.save(int(state["step"]), state,
                           extra={"cursor": consumed}, blocking=True)
        return state
