"""Sharded, asynchronous, atomic checkpointing (fault-tolerance substrate).

Design for 1000+ nodes (DESIGN.md §5):
  * **atomic commit** — writes land in ``step_N.tmp/``, fsync'd, then renamed
    to ``step_N/``; a crash mid-write never corrupts the latest checkpoint;
  * **async** — ``save()`` snapshots device arrays to host (cheap) and hands
    serialization to a background thread, keeping the step loop running;
  * **mesh-independent** — arrays are saved *logically* (full value per leaf);
    restore re-shards onto whatever mesh the restoring job runs, so an elastic
    restart on fewer/more hosts just works.  (A production multi-host variant
    writes per-shard files keyed by global offset — the format records the
    layout metadata needed to do that; on this single-process container every
    shard is local.)
  * **data cursor** — the pipeline position is stored with the weights, so a
    restart resumes mid-epoch without repeating or skipping batches.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_FLAT_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _FLAT_SEP.join(_path_part(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":      # npz has no bf16; widen lossless
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot to host, then serialize+commit in the background."""
        self.wait()  # one in-flight save at a time
        treedef = jax.tree.structure(state)
        flat = _flatten(state)   # device→host sync happens here, on purpose

        def work():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                meta = {
                    "step": step,
                    "treedef": str(treedef),
                    "keys": sorted(flat.keys()),
                    "extra": extra or {},
                    "time": time.time(),
                }
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)          # atomic commit
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template`` (re-shards on the
        current mesh via the template's shardings when jitted downstream)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        arrays = np.load(os.path.join(path, "arrays.npz"))
        flat_template, treedef = jax.tree.flatten(template)
        keys = []
        for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]:
            keys.append(_FLAT_SEP.join(_path_part(x) for x in p))
        leaves = []
        for key, tmpl in zip(keys, flat_template):
            arr = arrays[key]
            assert arr.shape == tuple(tmpl.shape), (key, arr.shape, tmpl.shape)
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return treedef.unflatten(leaves), meta.get("extra", {})

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
