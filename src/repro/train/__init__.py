"""Training substrate: optimizers, schedules, checkpointing, fault tolerance."""
from .checkpoint import CheckpointManager  # noqa: F401
from .optimizer import adafactor, adamw, get_optimizer  # noqa: F401
from .trainer import TrainConfig, Trainer, init_state, make_train_step  # noqa: F401
