"""Optimizers: AdamW and Adafactor, as pure (init, update) pairs over pytrees.

Sharding posture (ZeRO-ish): optimizer states inherit the parameter sharding
specs, so with params sharded P("data","model") the f32 moments shard the
same way — no replicated optimizer memory.  Adafactor factors the second
moment for the embedding-dominated archs (qwen3-moe, llama-90b, gemma3,
recurrentgemma) where AdamW's 2×f32 states would not fit per-chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=jnp.float32):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), tree)


# =============================================================================
# AdamW
# =============================================================================
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {
            "m": _tree_zeros_like(params),
            "v": _tree_zeros_like(params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        grads = clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mhat = m / (1 - b1 ** cf)
            vhat = v / (1 - b2 ** cf)
            step = mhat / (jnp.sqrt(vhat) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (step + weight_decay * pf)
            return pf.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update)


# =============================================================================
# Adafactor (factored second moment; no first moment by default)
# =============================================================================
def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              grad_clip: float = 1.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def st(x):
            if _factored(x.shape):
                return {
                    "vr": jnp.zeros(x.shape[:-1], jnp.float32),          # row
                    "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32),  # col
                }
            return {"v": jnp.zeros(x.shape, jnp.float32)}
        return {"v": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads = clip_by_global_norm(grads, grad_clip)
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(g.shape):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)[..., None]
                    * vc[..., None, :])
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                denom = jnp.sqrt(vv)
                nv = {"v": vv}
            step = gf / jnp.maximum(denom, eps)
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr * step - lr * weight_decay * pf
            return pf.astype(p.dtype), nv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v, "count": count}

    return Optimizer(init, update)


# =============================================================================
def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise ValueError(name)
