"""Fault tolerance & elasticity policies (DESIGN.md §5).

On a real 1000-node fleet the failure domain is the host: a dead host kills
its jax process and the collective; recovery is restart-from-checkpoint on a
(possibly smaller) mesh.  This module packages those policies so the trainer
and tests can exercise them deterministically on one process:

  * ``run_with_recovery`` — step-loop supervisor: on failure, restores the
    latest atomic checkpoint and resumes at the recorded data cursor (exactly-
    once batch semantics).  Failures are injected in tests via ``FailurePlan``.
  * ``elastic_remesh`` — rebuilds shardings for a new device count; since
    checkpoints are mesh-independent (logical arrays), restore-then-reshard is
    the entire elasticity story.
  * ``StragglerPolicy`` — prefetch-depth recommendation given observed step
    time jitter; the data pipeline's opportunistic scheduler consumes it (a
    straggling input shard must never stall the step loop).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from .trainer import Trainer


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure injection for tests: fail at these step numbers."""
    fail_at_steps: tuple = ()
    exc: type = RuntimeError
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise self.exc(f"injected failure at step {step}")


def run_with_recovery(trainer: Trainer, batch_source: Callable[[], Iterator[dict]],
                      steps: int, max_restarts: int = 3,
                      failure_plan: FailurePlan | None = None) -> Any:
    """Supervise the training loop; restart from checkpoint on failure."""
    assert trainer.ckpt is not None, "recovery requires a checkpoint dir"
    restarts = 0
    while True:
        try:
            state, extra = trainer.init_or_restore()
            cursor = extra.get("cursor", 0)

            def guarded(batches):
                for i, b in enumerate(batches):
                    if failure_plan is not None:
                        failure_plan.maybe_fail(i + 1)
                    yield b

            return trainer.fit(guarded(batch_source()), steps=steps,
                               state=state, cursor=cursor)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            # a real fleet would also re-admit replacement hosts here
            time.sleep(0.01)


@dataclasses.dataclass
class StragglerPolicy:
    """Prefetch-depth control: keep enough batches in flight that a shard
    straggling by k standard deviations never stalls the step."""
    target_sigma: float = 3.0
    min_depth: int = 2
    max_depth: int = 16

    def recommend_depth(self, step_times_s: list[float]) -> int:
        if len(step_times_s) < 4:
            return self.min_depth
        arr = np.asarray(step_times_s[-64:])
        mean, std = float(arr.mean()), float(arr.std())
        if mean <= 0:
            return self.min_depth
        depth = int(np.ceil(1 + self.target_sigma * std / mean))
        return int(np.clip(depth, self.min_depth, self.max_depth))


def elastic_remesh(n_devices: int, axes: tuple[str, ...] = ("data", "model"),
                   model_parallel: int | None = None):
    """Build the largest valid mesh for the surviving device count.

    Keeps the model axis fixed (TP degree is an architecture property) and
    shrinks the data axis — the standard elastic-DP policy."""
    devs = jax.devices()[:n_devices]
    mp = model_parallel or 1
    dp = max(1, len(devs) // mp)
    shape = (dp, mp) if len(axes) == 2 else (1, dp, mp)
    import numpy as _np
    arr = _np.asarray(devs[: int(_np.prod(shape))]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(arr, axes)
