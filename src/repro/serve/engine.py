"""Batched serving engine: continuous-batching prefill + decode.

A static (B, S_max) KV footprint with per-slot dynamic lengths — the
paged-lite layout the decode_attention kernel masks against.  Requests join
free slots (prefill teacher-forces the prompt through ``decode_step`` so
cache layout is identical to decode), then the engine steps all active slots
in lockstep; finished slots free immediately (continuous batching).

The engine is deliberately single-host here; the multi-pod story is the
serve_step dry-run in ``launch/dryrun.py`` (cache sharded over mesh axes),
which this engine's step function is lowered from.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.trace import Metrics
from ..models.model import Model
from ..data.tokenizer import EOS, PAD, HashTokenizer


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: list[int]
    max_new_tokens: int = 16
    out_ids: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, max_batch: int = 8,
                 max_seq: int = 512, greedy: bool = True):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.greedy = greedy
        self.cache = model.cache_init(max_batch, max_seq)
        self.slots: list[Request | None] = [None] * max_batch
        self._pending: list[Request] = []
        self._next_feed = np.zeros(max_batch, np.int64)     # token to feed next
        self._prompt_pos = np.zeros(max_batch, np.int64)    # progress in prompt
        self._decode = jax.jit(model.decode_step)
        self.metrics = Metrics("serve", steps=0, tokens_out=0, prefill_tokens=0)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._pending.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self._pending:
                req = self._pending.pop(0)
                self.slots[i] = req
                self._reset_slot(i)
                self._prompt_pos[i] = 0
                self._next_feed[i] = req.prompt_ids[0]

    def _reset_slot(self, i: int) -> None:
        """Zero slot i's cache lanes (length gating makes stale data inert,
        but zeroing keeps restarts reproducible)."""
        def zero_lane(x):
            # tail caches / length: (B, ...); scanned caches: (repeats, B, ...)
            if x.ndim >= 1 and x.shape[0] == self.max_batch:
                return x.at[i].set(jnp.zeros_like(x[i]))
            if x.ndim >= 2 and x.shape[1] == self.max_batch:
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        self.cache = jax.tree.map(zero_lane, self.cache)
        # per-slot length: cache["length"] is (B,)
        self.cache["length"] = self.cache["length"].at[i].set(0)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One lockstep decode over all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        feed = jnp.asarray(self._next_feed, jnp.int32)
        memory = None
        if self.model.cfg.cross_memory_len:
            memory = jnp.zeros((self.max_batch, self.model.cfg.cross_memory_len,
                                self.model.cfg.d_model), jnp.bfloat16)
        logits, self.cache = self._decode(self.params, feed, self.cache, memory)
        next_tok = np.asarray(jnp.argmax(logits, axis=-1))
        self.metrics.inc("steps")

        for i in active:
            req = self.slots[i]
            self._prompt_pos[i] += 1
            if self._prompt_pos[i] < len(req.prompt_ids):
                # still prefilling: teacher-force the next prompt token
                self._next_feed[i] = req.prompt_ids[self._prompt_pos[i]]
                self.metrics.inc("prefill_tokens")
                continue
            tok = int(next_tok[i])
            req.out_ids.append(tok)
            self.metrics.inc("tokens_out")
            self._next_feed[i] = tok
            if tok == EOS or len(req.out_ids) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None            # continuous batching: free now
        return len(active)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self._pending:
                return
        raise TimeoutError("serving did not drain")
